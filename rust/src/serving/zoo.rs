//! Detector model zoo — loads the `detector_s{0..3}_{res}` and
//! `preprocess_{res}` HLO artifacts and executes them through PJRT. This is
//! the *real* compute on the serving request path: the preprocessing step
//! runs the Pallas separable-bilinear kernel, the detectors run the conv
//! stacks, and the measured wall-clock durations feed the virtual-time
//! cluster as GPU/CPU service times.

use std::collections::HashMap;
use std::rc::Rc;
use std::time::Instant;

use anyhow::{Context, Result};

use crate::runtime::{lit_f32, to_vec_f32, Executable, Manifest, Runtime};

pub struct ModelZoo {
    /// (model, res) -> detector executable + input shape
    detectors: HashMap<(usize, usize), (Rc<Executable>, Vec<usize>)>,
    /// (model, res) -> verdict from the first stacked-batch attempt:
    /// `false` means the artifact is fixed-shape and `detect_batch` goes
    /// straight to the sequential fallback instead of re-paying a doomed
    /// stacked execution per batch.
    batchable: std::cell::RefCell<HashMap<(usize, usize), bool>>,
    /// res -> preprocess executable (1080-native input)
    preproc: HashMap<usize, Rc<Executable>>,
    /// res order from the manifest: index (action v) -> pixel resolution
    pub res_order: Vec<usize>,
    pub native_shape: Vec<usize>,
    pub n_scores: usize,
}

impl ModelZoo {
    pub fn load(rt: &Runtime, manifest: &Manifest) -> Result<ModelZoo> {
        anyhow::ensure!(
            !manifest.zoo.is_empty(),
            "manifest has no detector zoo — rebuild artifacts without --skip-zoo"
        );
        let mut detectors = HashMap::new();
        let mut n_scores = 0;
        for entry in &manifest.zoo {
            let exe = rt.load(&entry.file).with_context(|| {
                format!("loading detector {}", entry.file)
            })?;
            n_scores = entry.n_scores;
            detectors
                .insert((entry.model, entry.res), (exe, entry.input_shape.clone()));
        }
        let mut preproc = HashMap::new();
        let mut native_shape = Vec::new();
        for entry in &manifest.preprocess {
            native_shape = entry.input_shape.clone();
            preproc.insert(entry.res, rt.load(&entry.file)?);
        }
        Ok(ModelZoo {
            detectors,
            batchable: std::cell::RefCell::new(HashMap::new()),
            preproc,
            res_order: manifest.res_order.clone(),
            native_shape,
            n_scores,
        })
    }

    /// Pixel resolution for action index v.
    pub fn res_of_index(&self, v: usize) -> usize {
        self.res_order[v]
    }

    /// Run Pallas-resize preprocessing on a native frame. Returns the
    /// downsized frame and the measured wall-clock seconds. Resolution
    /// index 0 (native 1080P) is a no-op copy.
    pub fn preprocess(&self, v: usize, frame: &[f32]) -> Result<(Vec<f32>, f64)> {
        let res = self.res_of_index(v);
        let Some(exe) = self.preproc.get(&res) else {
            return Ok((frame.to_vec(), 0.0)); // native resolution
        };
        let t0 = Instant::now();
        let lit = lit_f32(frame, &self.native_shape)?;
        let outs = exe.run(&[lit])?;
        let out = to_vec_f32(&outs[0])?;
        Ok((out, t0.elapsed().as_secs_f64()))
    }

    /// Run a detector on a (already downsized) frame. Returns the score
    /// vector and the measured wall-clock seconds.
    pub fn detect(
        &self,
        model: usize,
        v: usize,
        frame: &[f32],
    ) -> Result<(Vec<f32>, f64)> {
        let res = self.res_of_index(v);
        let (exe, shape) = self
            .detectors
            .get(&(model, res))
            .with_context(|| format!("no detector for model {model} res {res}"))?;
        anyhow::ensure!(
            frame.len() == shape.iter().product::<usize>(),
            "frame has {} elems, detector {model}@{res} wants {:?}",
            frame.len(),
            shape
        );
        let t0 = Instant::now();
        let lit = lit_f32(frame, shape)?;
        let outs = exe.run(&[lit])?;
        let scores = to_vec_f32(&outs[0])?;
        Ok((scores, t0.elapsed().as_secs_f64()))
    }

    /// Run a detector over a batch of `k` frames (the one supplied frame
    /// replicated — the serving engine batches by (model, res), and the
    /// synthetic sources make frame content interchangeable). Attempts a
    /// single stacked execution with a leading batch dimension; artifacts
    /// compiled for a fixed single-frame shape reject the stacked literal,
    /// in which case the frames run sequentially and the measured
    /// wall-clock still covers the whole batch. Returns the concatenated
    /// scores and total elapsed seconds.
    pub fn detect_batch(
        &self,
        model: usize,
        v: usize,
        frame: &[f32],
        k: usize,
    ) -> Result<(Vec<f32>, f64)> {
        if k <= 1 {
            return self.detect(model, v, frame);
        }
        let res = self.res_of_index(v);
        let (exe, shape) = self
            .detectors
            .get(&(model, res))
            .with_context(|| format!("no detector for model {model} res {res}"))?;
        anyhow::ensure!(
            frame.len() == shape.iter().product::<usize>(),
            "frame has {} elems, detector {model}@{res} wants {:?}",
            frame.len(),
            shape
        );
        let try_stacked =
            self.batchable.borrow().get(&(model, res)).copied() != Some(false);
        if try_stacked {
            // leading batch dim: replace a leading 1, else prepend k
            let mut batch_shape = shape.clone();
            if batch_shape.first() == Some(&1) {
                batch_shape[0] = k;
            } else {
                batch_shape.insert(0, k);
            }
            let mut stacked = Vec::with_capacity(frame.len() * k);
            for _ in 0..k {
                stacked.extend_from_slice(frame);
            }
            let t0 = Instant::now();
            let stacked_run =
                lit_f32(&stacked, &batch_shape).and_then(|lit| exe.run(&[lit]));
            match stacked_run {
                Ok(outs) => {
                    self.batchable.borrow_mut().insert((model, res), true);
                    let scores = to_vec_f32(&outs[0])?;
                    return Ok((scores, t0.elapsed().as_secs_f64()));
                }
                Err(e) => {
                    // Remember the verdict so later batches skip straight
                    // to the sequential path — and say why once, since a
                    // transient failure caught here degrades this
                    // (model, res) to sequential for the process lifetime.
                    eprintln!(
                        "detector {model}@{res}: stacked batch rejected, \
                         falling back to sequential ({e:#})"
                    );
                    self.batchable.borrow_mut().insert((model, res), false);
                }
            }
        }
        let t0 = Instant::now();
        let mut all = Vec::new();
        for _ in 0..k {
            let lit = lit_f32(frame, shape)?;
            let outs = exe.run(&[lit])?;
            all.extend(to_vec_f32(&outs[0])?);
        }
        Ok((all, t0.elapsed().as_secs_f64()))
    }
}
