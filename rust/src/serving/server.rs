//! The online serving loop, PJRT-backed: policy-routed requests over the
//! virtual-time edge cluster with *real* PJRT compute (Pallas preprocessing
//! + detector zoo) supplying the service times. Batches pulled by a node's
//! GPU run as one stacked zoo execution when the artifact accepts a leading
//! batch dimension (sequential fallback otherwise). The options/report
//! layer lives dep-free in [`crate::serving::engine`].
//!
//! The control plane is the unified [`Policy`] trait: the trained actor
//! runs through [`PolicyController`] — the same adapter the simulator
//! evaluation uses — and the fallback is the shared shortest-queue
//! baseline. Per-instant decision caching (all arrivals of one event time
//! share one actor forward pass) lives inside `EdgeCluster`.

use anyhow::Result;

use crate::baselines::{Selection, ShortestQueueController};
use crate::coordinator::cluster::ComputeHook;
use crate::policy::Policy;
use crate::rl::policy::{ActorPolicy, PolicyController};
use crate::runtime::{Manifest, Runtime};
use crate::serving::engine::{ServingOptions, ServingReport};
use crate::serving::frames::FrameSource;
use crate::serving::zoo::ModelZoo;

/// Real-compute hook: every preprocess/detect call generates a frame and
/// executes the actual HLO artifacts, feeding measured durations into the
/// virtual clock. Batched detections stack frames into one execution.
struct RealCompute<'a> {
    zoo: &'a ModelZoo,
    frames: FrameSource,
    preproc_calls: usize,
    preproc_secs: f64,
    detect_calls: usize,
    detect_secs: f64,
    /// downsized frame cache per resolution index (reused across detects)
    last_frames: Vec<Option<Vec<f32>>>,
}

impl<'a> RealCompute<'a> {
    fn new(zoo: &'a ModelZoo, seed: u64) -> Self {
        let h = zoo.native_shape[0];
        let w = zoo.native_shape[1];
        RealCompute {
            zoo,
            frames: FrameSource::new(h, w, seed),
            preproc_calls: 0,
            preproc_secs: 0.0,
            detect_calls: 0,
            detect_secs: 0.0,
            last_frames: vec![None; 8],
        }
    }

    /// Make sure a downsized frame for `res` is cached (first detect of a
    /// resolution before any preprocess call lands here; synthetic frame
    /// content is interchangeable, so detects borrow the cached frame).
    fn ensure_frame(&mut self, res: usize) -> Result<()> {
        if self.last_frames[res].is_none() {
            let native = self.frames.next_frame();
            let (down, _) = self.zoo.preprocess(res, &native)?;
            self.last_frames[res] = Some(down);
        }
        Ok(())
    }
}

impl ComputeHook for RealCompute<'_> {
    fn preprocess(&mut self, _node: usize, res: usize) -> Result<f64> {
        let frame = self.frames.next_frame();
        let (down, secs) = self.zoo.preprocess(res, &frame)?;
        self.last_frames[res] = Some(down);
        self.preproc_calls += 1;
        self.preproc_secs += secs;
        Ok(secs)
    }

    fn detect(&mut self, _node: usize, model: usize, res: usize) -> Result<f64> {
        self.ensure_frame(res)?;
        // invariant: ensure_frame populated last_frames[res] above
        let frame = self.last_frames[res].as_deref().unwrap();
        let (_scores, secs) = self.zoo.detect(model, res, frame)?;
        self.detect_calls += 1;
        self.detect_secs += secs;
        Ok(secs)
    }

    fn detect_batch(
        &mut self,
        _node: usize,
        model: usize,
        res: usize,
        k: usize,
    ) -> Result<f64> {
        self.ensure_frame(res)?;
        // invariant: ensure_frame populated last_frames[res] above
        let frame = self.last_frames[res].as_deref().unwrap();
        let (_scores, secs) = self.zoo.detect_batch(model, res, frame, k)?;
        self.detect_calls += k;
        self.detect_secs += secs;
        Ok(secs)
    }
}

/// Run the serving loop end to end. `policy_blob` is an actor-prefix
/// checkpoint (None = shortest-queue fallback).
pub fn run_serving(
    rt: &Runtime,
    manifest: &Manifest,
    policy_blob: Option<&[f32]>,
    opts: &ServingOptions,
) -> Result<ServingReport> {
    let zoo = ModelZoo::load(rt, manifest)?;
    // the actor's lowering fixes the observation history window
    let mut opts = opts.clone();
    opts.scenario.hist_len = manifest.net.hist_len;
    let mut cluster = crate::serving::engine::build_cluster(&opts);
    let mut compute = RealCompute::new(&zoo, opts.seed);

    let mut policy: Box<dyn Policy> = match policy_blob {
        Some(blob) => {
            // fail loudly on a node-count mismatch rather than silently
            // re-deriving the scenario (which would drop caller tweaks);
            // resolve the scenario at the artifact's node count upstream
            // (Scenario::at_nodes / with_nodes) when scaling is wanted
            anyhow::ensure!(
                opts.scenario.n_nodes == manifest.net.n_agents,
                "scenario {:?} has {} nodes but the actor artifacts are \
                 lowered for {} agents",
                opts.scenario.name,
                opts.scenario.n_nodes,
                manifest.net.n_agents
            );
            Box::new(PolicyController::new(
                "actor",
                ActorPolicy::with_params(rt, manifest, blob, false)?,
                opts.seed ^ 0xACE,
                opts.greedy,
            ))
        }
        None => Box::new(ShortestQueueController::new(Selection::Min)),
    };

    policy.reset(opts.seed);
    cluster.run(policy.as_mut(), &mut compute, opts.duration_virtual_secs)?;

    let mean_preproc_ms = if compute.preproc_calls == 0 {
        0.0
    } else {
        1e3 * compute.preproc_secs / compute.preproc_calls as f64
    };
    let mean_detect_ms = if compute.detect_calls == 0 {
        0.0
    } else {
        1e3 * compute.detect_secs / compute.detect_calls as f64
    };
    Ok(ServingReport::from_cluster(
        &cluster,
        &opts.scenario.name,
        opts.duration_virtual_secs,
        mean_preproc_ms,
        mean_detect_ms,
    ))
}
