//! The online serving loop: policy-routed requests over the virtual-time
//! edge cluster with *real* PJRT compute (Pallas preprocessing + detector
//! zoo) supplying the service times. Produces the latency/throughput
//! report the serving benchmark and the end-to-end example print.

use anyhow::Result;

use crate::coordinator::cluster::{ComputeHook, EdgeCluster, ServingPolicy};
use crate::env::bandwidth::BandwidthConfig;
use crate::env::profiles::Profiles;
use crate::env::workload::WorkloadConfig;
use crate::env::Action;
use crate::rl::policy::ActorPolicy;
use crate::runtime::{Manifest, Runtime};
use crate::serving::frames::FrameSource;
use crate::serving::zoo::ModelZoo;
use crate::util::rng::Rng;
use crate::util::stats::percentile;

/// Serving-run options.
#[derive(Debug, Clone)]
pub struct ServingOptions {
    pub n_nodes: usize,
    pub duration_virtual_secs: f64,
    pub drop_deadline: f64,
    pub seed: u64,
    /// Use the trained policy (blob) or the shortest-queue fallback.
    pub greedy: bool,
}

impl Default for ServingOptions {
    fn default() -> Self {
        ServingOptions {
            n_nodes: 4,
            duration_virtual_secs: 30.0,
            drop_deadline: 1.5,
            seed: 0,
            greedy: true,
        }
    }
}

/// End-of-run report.
#[derive(Debug, Clone)]
pub struct ServingReport {
    pub total: usize,
    pub completed: usize,
    pub dropped: usize,
    pub dispatched: usize,
    pub virtual_secs: f64,
    pub throughput_rps: f64,
    pub mean_latency: f64,
    pub p50_latency: f64,
    pub p95_latency: f64,
    pub p99_latency: f64,
    pub mean_accuracy: f64,
    /// Mean measured PJRT wall-clock per preprocess / detect call.
    pub mean_preproc_ms: f64,
    pub mean_detect_ms: f64,
}

impl ServingReport {
    pub fn print(&self) {
        println!("serving report:");
        println!("  requests        {}", self.total);
        println!("  completed       {}", self.completed);
        println!(
            "  dropped         {} ({:.1}%)",
            self.dropped,
            100.0 * self.dropped as f64 / self.total.max(1) as f64
        );
        println!("  dispatched      {}", self.dispatched);
        println!("  throughput      {:.1} req/s (virtual)", self.throughput_rps);
        println!(
            "  latency         mean {:.0} ms, p50 {:.0} ms, p95 {:.0} ms, p99 {:.0} ms",
            self.mean_latency * 1e3,
            self.p50_latency * 1e3,
            self.p95_latency * 1e3,
            self.p99_latency * 1e3
        );
        println!("  mean accuracy   {:.4}", self.mean_accuracy);
        println!(
            "  real exec       preprocess {:.2} ms, detect {:.2} ms (PJRT wall-clock)",
            self.mean_preproc_ms, self.mean_detect_ms
        );
    }
}

/// Policy adapter: trained actor over cluster observations, with per-event
/// caching so all nodes of one decision instant share one forward pass.
struct ActorServingPolicy {
    policy: ActorPolicy,
    rng: Rng,
    greedy: bool,
    cache_t: f64,
    cache: Vec<Action>,
    obs_scratch: Vec<f32>,
}

impl ServingPolicy for ActorServingPolicy {
    fn decide(&mut self, cluster: &EdgeCluster, node: usize) -> Result<Action> {
        if cluster.now() != self.cache_t || self.cache.is_empty() {
            self.obs_scratch.clear();
            for i in 0..cluster.n_nodes {
                cluster.observation_into(i, &mut self.obs_scratch);
            }
            let (actions, _) =
                self.policy.act(&self.obs_scratch, &mut self.rng, self.greedy)?;
            self.cache = actions;
            self.cache_t = cluster.now();
        }
        Ok(self.cache[node])
    }
}

/// Shortest-queue fallback policy (no trained blob supplied).
struct ShortestQueuePolicy;

impl ServingPolicy for ShortestQueuePolicy {
    fn decide(&mut self, cluster: &EdgeCluster, _node: usize) -> Result<Action> {
        let mut best = 0;
        for j in 1..cluster.n_nodes {
            if cluster.queue_len(j) < cluster.queue_len(best) {
                best = j;
            }
        }
        Ok(Action::new(best, 1, 2))
    }
}

/// Real-compute hook: every preprocess/detect call generates a frame and
/// executes the actual HLO artifacts, feeding measured durations into the
/// virtual clock.
struct RealCompute<'a> {
    zoo: &'a ModelZoo,
    frames: FrameSource,
    preproc_calls: usize,
    preproc_secs: f64,
    detect_calls: usize,
    detect_secs: f64,
    /// downsized frame cache per resolution index (reused across detects)
    last_frames: Vec<Option<Vec<f32>>>,
}

impl<'a> RealCompute<'a> {
    fn new(zoo: &'a ModelZoo, seed: u64) -> Self {
        let h = zoo.native_shape[0];
        let w = zoo.native_shape[1];
        RealCompute {
            zoo,
            frames: FrameSource::new(h, w, seed),
            preproc_calls: 0,
            preproc_secs: 0.0,
            detect_calls: 0,
            detect_secs: 0.0,
            last_frames: vec![None; 8],
        }
    }
}

impl ComputeHook for RealCompute<'_> {
    fn preprocess(&mut self, _node: usize, res: usize) -> Result<f64> {
        let frame = self.frames.next_frame();
        let (down, secs) = self.zoo.preprocess(res, &frame)?;
        self.last_frames[res] = Some(down);
        self.preproc_calls += 1;
        self.preproc_secs += secs;
        Ok(secs)
    }

    fn detect(&mut self, _node: usize, model: usize, res: usize) -> Result<f64> {
        let frame = match &self.last_frames[res] {
            Some(f) => f.clone(),
            None => {
                let native = self.frames.next_frame();
                let (down, _) = self.zoo.preprocess(res, &native)?;
                down
            }
        };
        let (_scores, secs) = self.zoo.detect(model, res, &frame)?;
        self.detect_calls += 1;
        self.detect_secs += secs;
        Ok(secs)
    }
}

/// Run the serving loop end to end. `policy_blob` is an actor-prefix
/// checkpoint (None = shortest-queue fallback).
pub fn run_serving(
    rt: &Runtime,
    manifest: &Manifest,
    policy_blob: Option<&[f32]>,
    opts: &ServingOptions,
) -> Result<ServingReport> {
    let zoo = ModelZoo::load(rt, manifest)?;
    let mut cluster = EdgeCluster::new(
        opts.n_nodes,
        WorkloadConfig::default(),
        BandwidthConfig { n_nodes: opts.n_nodes, ..BandwidthConfig::default() },
        Profiles::default(),
        0.2,
        opts.drop_deadline,
        manifest.net.hist_len,
        opts.seed,
    );
    let mut compute = RealCompute::new(&zoo, opts.seed);

    let mut policy: Box<dyn ServingPolicy> = match policy_blob {
        Some(blob) => Box::new(ActorServingPolicy {
            policy: ActorPolicy::with_params(rt, manifest, blob, false)?,
            rng: Rng::new(opts.seed ^ 0xACE),
            greedy: opts.greedy,
            cache_t: -1.0,
            cache: Vec::new(),
            obs_scratch: Vec::new(),
        }),
        None => Box::new(ShortestQueuePolicy),
    };

    cluster.run(policy.as_mut(), &mut compute, opts.duration_virtual_secs)?;

    let served = &cluster.served;
    let total = served.len();
    let completed: Vec<_> = served.iter().filter(|s| !s.dropped).collect();
    let latencies: Vec<f64> = completed.iter().map(|s| s.latency()).collect();
    let dropped = total - completed.len();
    Ok(ServingReport {
        total,
        completed: completed.len(),
        dropped,
        dispatched: served.iter().filter(|s| s.origin != s.target).count(),
        virtual_secs: opts.duration_virtual_secs,
        throughput_rps: completed.len() as f64 / opts.duration_virtual_secs,
        mean_latency: crate::util::stats::mean(&latencies),
        p50_latency: percentile(&latencies, 50.0),
        p95_latency: percentile(&latencies, 95.0),
        p99_latency: percentile(&latencies, 99.0),
        mean_accuracy: if completed.is_empty() {
            0.0
        } else {
            completed.iter().map(|s| s.accuracy).sum::<f64>()
                / completed.len() as f64
        },
        mean_preproc_ms: if compute.preproc_calls == 0 {
            0.0
        } else {
            1e3 * compute.preproc_secs / compute.preproc_calls as f64
        },
        mean_detect_ms: if compute.detect_calls == 0 {
            0.0
        } else {
            1e3 * compute.detect_secs / compute.detect_calls as f64
        },
    })
}
