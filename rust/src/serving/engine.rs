//! Dep-free serving engine — the options/report layer of the online
//! serving loop, shared by the PJRT-backed server (`serving::server`) and
//! the profile-table path (tests, the dep-free `serving_throughput` bench,
//! capacity planning). Builds on the invariant-checked [`EdgeCluster`]:
//! GPU mutual exclusion per node, request conservation
//! (`emitted == completed + dropped + residual`), and per-(model, res)
//! batched service.
//!
//! Unified control plane: runs are parameterized by a
//! [`Scenario`] descriptor and driven by any [`Policy`] — the same trait
//! the slot simulator's evaluation harness consumes, so an RL-vs-baseline
//! comparison on the real serving core under any registered scenario is
//! one [`serve_scenario`] call. The engine's former private
//! `ShortestQueuePolicy` is retired: the shortest-queue baseline
//! ([`crate::baselines::ShortestQueueController`]) is the one
//! implementation serving both layers.

use anyhow::Result;

use crate::baselines::{Selection, ShortestQueueController};
use crate::coordinator::cluster::{ComputeHook, EdgeCluster, ProfileCompute};
use crate::policy::Policy;
use crate::scenario::Scenario;
use crate::telemetry::trace::{TraceRing, TraceSink};
use crate::util::stats::{mean, percentile};

/// Serving-run options: a [`Scenario`] descriptor (workload, bandwidth,
/// heterogeneity, deadline, batching knobs) plus the run-level knobs that
/// are not part of the regime itself.
#[derive(Debug, Clone)]
pub struct ServingOptions {
    pub scenario: Scenario,
    pub duration_virtual_secs: f64,
    pub seed: u64,
    /// Greedy (argmax) vs sampled execution of a trained policy. Read
    /// only by the PJRT `run_serving`, which constructs the actor itself;
    /// the dep-free paths receive a pre-built policy, whose execution
    /// mode was fixed at construction.
    pub greedy: bool,
}

impl Default for ServingOptions {
    fn default() -> Self {
        ServingOptions {
            scenario: Scenario::default(),
            duration_virtual_secs: 30.0,
            seed: 0,
            greedy: true,
        }
    }
}

impl ServingOptions {
    /// Options for a registered scenario with the default run knobs.
    pub fn for_scenario(name: &str) -> Result<Self> {
        Ok(ServingOptions {
            scenario: Scenario::by_name(name)?,
            ..Default::default()
        })
    }
}

/// End-of-run report. Request accounting is exhaustive:
/// `emitted + imported ==
///  completed + dropped + lost_to_failure + shed + cancelled + residual
///  + exported`
/// (the boundary terms are zero outside the sharded fleet runtime, where
/// the per-shard reports carry cross-shard traffic; `lost_to_failure` is
/// zero unless the scenario injects faults; `shed` is zero unless the
/// scenario runs an open-loop ingest with admission enabled; `cancelled`
/// is zero unless the policy hedges).
#[derive(Debug, Clone)]
pub struct ServingReport {
    /// Scenario the run was parameterized by.
    pub scenario: String,
    /// Requests emitted into the cluster over the horizon.
    pub emitted: usize,
    /// Requests that entered over a cross-shard boundary (fleet shards
    /// only; 0 for unsharded runs).
    pub imported: usize,
    /// Requests that left over a cross-shard boundary (fleet shards only).
    pub exported: usize,
    /// Requests resolved (completed or dropped) by end of run.
    pub total: usize,
    pub completed: usize,
    pub dropped: usize,
    /// Requests still in flight when the horizon cut the run.
    pub residual: usize,
    /// Requests destroyed by injected faults (crashed-node queues and
    /// in-flight batches, arrivals/deliveries at dead nodes). Exactly 0
    /// for fault-free scenarios.
    pub lost_to_failure: usize,
    /// Open-loop arrivals refused by the admission gate. Exactly 0 for
    /// closed-loop scenarios.
    pub shed: usize,
    /// Hedge copies retired because their twin reached GPU service first.
    /// Exactly 0 unless the policy hedges.
    pub cancelled: usize,
    pub dispatched: usize,
    /// GPU batch executions and their size distribution.
    pub batches: usize,
    pub mean_batch_size: f64,
    pub max_batch_size: usize,
    pub virtual_secs: f64,
    pub throughput_rps: f64,
    pub mean_latency: f64,
    pub p50_latency: f64,
    pub p95_latency: f64,
    pub p99_latency: f64,
    pub mean_accuracy: f64,
    /// Mean measured PJRT wall-clock per preprocess / detect call
    /// (0.0 on the profile-table path).
    pub mean_preproc_ms: f64,
    pub mean_detect_ms: f64,
}

impl ServingReport {
    /// Build the report from a finished cluster run. `mean_preproc_ms` /
    /// `mean_detect_ms` are the real-compute wall-clock means (0.0 when
    /// profile tables supplied the durations).
    pub fn from_cluster(
        cluster: &EdgeCluster,
        scenario: &str,
        virtual_secs: f64,
        mean_preproc_ms: f64,
        mean_detect_ms: f64,
    ) -> Self {
        let served = &cluster.served;
        let total = served.len();
        let completed: Vec<_> = served.iter().filter(|s| !s.dropped).collect();
        let latencies: Vec<f64> = completed.iter().map(|s| s.latency()).collect();
        let dropped = total - completed.len();
        let mut batches = 0usize;
        let mut max_batch_size = 0usize;
        let mut batch_frames = 0usize;
        let mut last_batch = u64::MAX;
        for s in served.iter().filter(|s| s.batch_size > 0) {
            // batch members are recorded contiguously per execution
            if s.batch_id != last_batch {
                last_batch = s.batch_id;
                batches += 1;
                batch_frames += s.batch_size;
                max_batch_size = max_batch_size.max(s.batch_size);
            }
        }
        ServingReport {
            scenario: scenario.to_string(),
            emitted: cluster.emitted as usize,
            imported: cluster.imported as usize,
            exported: cluster.exported as usize,
            total,
            completed: completed.len(),
            dropped,
            residual: cluster.residual as usize,
            lost_to_failure: cluster.lost_to_failure as usize,
            shed: cluster.shed as usize,
            cancelled: cluster.cancelled as usize,
            dispatched: served.iter().filter(|s| s.origin != s.target).count(),
            batches,
            mean_batch_size: if batches == 0 {
                0.0
            } else {
                batch_frames as f64 / batches as f64
            },
            max_batch_size,
            virtual_secs,
            throughput_rps: completed.len() as f64 / virtual_secs,
            mean_latency: mean(&latencies),
            p50_latency: percentile(&latencies, 50.0),
            p95_latency: percentile(&latencies, 95.0),
            p99_latency: percentile(&latencies, 99.0),
            mean_accuracy: if completed.is_empty() {
                0.0
            } else {
                completed.iter().map(|s| s.accuracy).sum::<f64>()
                    / completed.len() as f64
            },
            mean_preproc_ms,
            mean_detect_ms,
        }
    }

    /// Request conservation: every request that entered (emitted locally
    /// or imported over a shard boundary) is accounted for (served,
    /// dropped, destroyed by a fault, shed at the admission gate,
    /// hedge-cancelled, still in flight, or exported to another shard).
    /// For unsharded closed-loop fault-free runs the extra terms are zero
    /// and this reduces to `emitted == completed + dropped + residual`.
    pub fn conserved(&self) -> bool {
        self.emitted + self.imported
            == self.completed
                + self.dropped
                + self.lost_to_failure
                + self.shed
                + self.cancelled
                + self.residual
                + self.exported
    }

    pub fn print(&self) {
        println!("serving report (scenario: {}):", self.scenario);
        println!("  emitted         {}", self.emitted);
        println!("  completed       {}", self.completed);
        println!(
            "  dropped         {} ({:.1}%)",
            self.dropped,
            100.0 * self.dropped as f64 / self.total.max(1) as f64
        );
        println!("  residual        {} (in flight at horizon)", self.residual);
        if self.lost_to_failure > 0 {
            println!(
                "  lost to failure {} (destroyed by injected faults)",
                self.lost_to_failure
            );
        }
        if self.shed > 0 {
            println!(
                "  shed            {} (refused at the admission gate)",
                self.shed
            );
        }
        if self.cancelled > 0 {
            println!(
                "  hedge-cancelled {} (twin reached service first)",
                self.cancelled
            );
        }
        if self.imported + self.exported > 0 {
            println!(
                "  cross-shard     {} in / {} out",
                self.imported, self.exported
            );
        }
        println!("  dispatched      {}", self.dispatched);
        println!(
            "  gpu batches     {} (mean size {:.2}, max {})",
            self.batches, self.mean_batch_size, self.max_batch_size
        );
        println!("  throughput      {:.1} req/s (virtual)", self.throughput_rps);
        println!(
            "  latency         mean {:.0} ms, p50 {:.0} ms, p95 {:.0} ms, p99 {:.0} ms",
            self.mean_latency * 1e3,
            self.p50_latency * 1e3,
            self.p95_latency * 1e3,
            self.p99_latency * 1e3
        );
        println!("  mean accuracy   {:.4}", self.mean_accuracy);
        println!(
            "  real exec       preprocess {:.2} ms, detect {:.2} ms (PJRT wall-clock)",
            self.mean_preproc_ms, self.mean_detect_ms
        );
    }
}

/// Build the serving cluster the engine runs over, straight from the
/// options' scenario descriptor.
pub fn build_cluster(opts: &ServingOptions) -> EdgeCluster {
    EdgeCluster::new(&opts.scenario, opts.seed)
}

/// Run the serving loop with the supplied policy/compute pair and report.
pub fn run_with(
    opts: &ServingOptions,
    policy: &mut dyn Policy,
    compute: &mut dyn ComputeHook,
) -> Result<(EdgeCluster, ServingReport)> {
    let mut cluster = build_cluster(opts);
    policy.reset(opts.seed);
    cluster.run(policy, compute, opts.duration_virtual_secs)?;
    let report = ServingReport::from_cluster(
        &cluster,
        &opts.scenario.name,
        opts.duration_virtual_secs,
        0.0,
        0.0,
    );
    Ok((cluster, report))
}

/// The fig6-style one-call API: run any unified `Policy` on the
/// event-driven serving engine under a scenario descriptor with
/// profile-table compute, and report with full request accounting.
pub fn serve_scenario(
    policy: &mut dyn Policy,
    scenario: &Scenario,
    duration_virtual_secs: f64,
    seed: u64,
) -> Result<ServingReport> {
    let opts = ServingOptions {
        scenario: scenario.clone(),
        duration_virtual_secs,
        seed,
        ..Default::default()
    };
    let mut compute = ProfileCompute::new(scenario.profiles.clone());
    let (_, report) = run_with(&opts, policy, &mut compute)?;
    Ok(report)
}

/// [`serve_scenario`] with the flight recorder enabled: the run records
/// every request-lifecycle, GPU-batch and fault event into a
/// preallocated ring of `ring_cap` records (virtual time only) and
/// returns it alongside the report. The recorded run is bit-identical to
/// the untraced one — the sink never touches RNG, heap layout, or event
/// order (pinned by `tests/trace.rs`).
pub fn serve_scenario_traced(
    policy: &mut dyn Policy,
    scenario: &Scenario,
    duration_virtual_secs: f64,
    seed: u64,
    ring_cap: usize,
) -> Result<(ServingReport, TraceRing)> {
    let opts = ServingOptions {
        scenario: scenario.clone(),
        duration_virtual_secs,
        seed,
        ..Default::default()
    };
    let mut compute = ProfileCompute::new(scenario.profiles.clone());
    let mut cluster = build_cluster(&opts);
    cluster.set_trace(TraceSink::ring(ring_cap));
    policy.reset(opts.seed);
    cluster.run(policy, &mut compute, opts.duration_virtual_secs)?;
    let report = ServingReport::from_cluster(
        &cluster,
        &opts.scenario.name,
        opts.duration_virtual_secs,
        0.0,
        0.0,
    );
    // invariant: the sink was installed as a ring three lines up and
    // nothing detaches it mid-run
    let ring = cluster.take_trace().unwrap();
    Ok((report, ring))
}

/// Dep-free serving run: the shortest-queue baseline (the same
/// implementation the simulator evaluation uses) over profile-table
/// compute. The engine bench and the offline tests drive this; the PJRT
/// server (`serving::server::run_serving`) swaps in real compute and the
/// trained actor.
pub fn run_profile_serving(opts: &ServingOptions) -> Result<ServingReport> {
    let mut policy = ShortestQueueController::new(Selection::Min);
    let mut compute = ProfileCompute::new(opts.scenario.profiles.clone());
    let (_, report) = run_with(opts, &mut policy, &mut compute)?;
    Ok(report)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn profile_serving_report_is_conserved() {
        let opts = ServingOptions {
            duration_virtual_secs: 10.0,
            ..Default::default()
        };
        let report = run_profile_serving(&opts).unwrap();
        assert_eq!(report.scenario, "paper");
        assert!(report.emitted > 0);
        assert!(report.completed > 0);
        assert!(report.conserved(), "{report:?}");
        assert!(report.throughput_rps > 0.0);
        assert!(report.p99_latency >= report.p50_latency);
    }

    #[test]
    fn batch_stats_count_each_execution_once() {
        let mut opts = ServingOptions {
            duration_virtual_secs: 15.0,
            seed: 3,
            ..Default::default()
        };
        // concentrate load so multi-frame batches form
        opts.scenario.workload.means = vec![4.0; opts.scenario.n_nodes];
        let report = run_profile_serving(&opts).unwrap();
        assert!(report.batches > 0);
        assert!(report.mean_batch_size >= 1.0);
        assert!(report.max_batch_size <= opts.scenario.max_batch);
    }

    #[test]
    fn serve_scenario_runs_baselines_on_engine() {
        // the acceptance shape: baseline policies produce conserved
        // reports straight from a named scenario
        let sc = Scenario::by_name("hotspot").unwrap();
        let mut policy = ShortestQueueController::new(Selection::Max);
        let report = serve_scenario(&mut policy, &sc, 8.0, 1).unwrap();
        assert_eq!(report.scenario, "hotspot");
        assert!(report.emitted > 0);
        assert!(report.conserved());
    }
}
