//! Dep-free serving engine — the options/report/policy layer of the online
//! serving loop, shared by the PJRT-backed server (`serving::server`) and
//! the profile-table path (tests, the dep-free `serving_throughput` bench,
//! capacity planning). Builds on the invariant-checked [`EdgeCluster`]:
//! GPU mutual exclusion per node, request conservation
//! (`emitted == completed + dropped + residual`), and per-(model, res)
//! batched service.

use anyhow::Result;

use crate::coordinator::cluster::{
    ComputeHook, EdgeCluster, ProfileCompute, ServingPolicy,
};
use crate::env::bandwidth::BandwidthConfig;
use crate::env::profiles::Profiles;
use crate::env::workload::WorkloadConfig;
use crate::env::Action;
use crate::util::stats::{mean, percentile};

/// Serving-run options.
#[derive(Debug, Clone)]
pub struct ServingOptions {
    pub n_nodes: usize,
    pub duration_virtual_secs: f64,
    pub drop_deadline: f64,
    pub seed: u64,
    /// Use the trained policy (blob) or the shortest-queue fallback.
    pub greedy: bool,
    /// Largest per-(model, res) GPU batch a node pulls at once.
    pub max_batch: usize,
    /// Longest a ready frame waits (virtual seconds) for batch-mates
    /// before an idle GPU pulls its lane anyway.
    pub batch_wait: f64,
}

impl Default for ServingOptions {
    fn default() -> Self {
        ServingOptions {
            n_nodes: 4,
            duration_virtual_secs: 30.0,
            drop_deadline: 1.5,
            seed: 0,
            greedy: true,
            max_batch: 8,
            batch_wait: 0.004,
        }
    }
}

/// End-of-run report. Request accounting is exhaustive:
/// `emitted == completed + dropped + residual`.
#[derive(Debug, Clone)]
pub struct ServingReport {
    /// Requests emitted into the cluster over the horizon.
    pub emitted: usize,
    /// Requests resolved (completed or dropped) by end of run.
    pub total: usize,
    pub completed: usize,
    pub dropped: usize,
    /// Requests still in flight when the horizon cut the run.
    pub residual: usize,
    pub dispatched: usize,
    /// GPU batch executions and their size distribution.
    pub batches: usize,
    pub mean_batch_size: f64,
    pub max_batch_size: usize,
    pub virtual_secs: f64,
    pub throughput_rps: f64,
    pub mean_latency: f64,
    pub p50_latency: f64,
    pub p95_latency: f64,
    pub p99_latency: f64,
    pub mean_accuracy: f64,
    /// Mean measured PJRT wall-clock per preprocess / detect call
    /// (0.0 on the profile-table path).
    pub mean_preproc_ms: f64,
    pub mean_detect_ms: f64,
}

impl ServingReport {
    /// Build the report from a finished cluster run. `mean_preproc_ms` /
    /// `mean_detect_ms` are the real-compute wall-clock means (0.0 when
    /// profile tables supplied the durations).
    pub fn from_cluster(
        cluster: &EdgeCluster,
        virtual_secs: f64,
        mean_preproc_ms: f64,
        mean_detect_ms: f64,
    ) -> Self {
        let served = &cluster.served;
        let total = served.len();
        let completed: Vec<_> = served.iter().filter(|s| !s.dropped).collect();
        let latencies: Vec<f64> = completed.iter().map(|s| s.latency()).collect();
        let dropped = total - completed.len();
        let mut batches = 0usize;
        let mut max_batch_size = 0usize;
        let mut batch_frames = 0usize;
        let mut last_batch = u64::MAX;
        for s in served.iter().filter(|s| s.batch_size > 0) {
            // batch members are recorded contiguously per execution
            if s.batch_id != last_batch {
                last_batch = s.batch_id;
                batches += 1;
                batch_frames += s.batch_size;
                max_batch_size = max_batch_size.max(s.batch_size);
            }
        }
        ServingReport {
            emitted: cluster.emitted as usize,
            total,
            completed: completed.len(),
            dropped,
            residual: cluster.residual as usize,
            dispatched: served.iter().filter(|s| s.origin != s.target).count(),
            batches,
            mean_batch_size: if batches == 0 {
                0.0
            } else {
                batch_frames as f64 / batches as f64
            },
            max_batch_size,
            virtual_secs,
            throughput_rps: completed.len() as f64 / virtual_secs,
            mean_latency: mean(&latencies),
            p50_latency: percentile(&latencies, 50.0),
            p95_latency: percentile(&latencies, 95.0),
            p99_latency: percentile(&latencies, 99.0),
            mean_accuracy: if completed.is_empty() {
                0.0
            } else {
                completed.iter().map(|s| s.accuracy).sum::<f64>()
                    / completed.len() as f64
            },
            mean_preproc_ms,
            mean_detect_ms,
        }
    }

    /// Request conservation: every emitted request is accounted for.
    pub fn conserved(&self) -> bool {
        self.emitted == self.completed + self.dropped + self.residual
    }

    pub fn print(&self) {
        println!("serving report:");
        println!("  emitted         {}", self.emitted);
        println!("  completed       {}", self.completed);
        println!(
            "  dropped         {} ({:.1}%)",
            self.dropped,
            100.0 * self.dropped as f64 / self.total.max(1) as f64
        );
        println!("  residual        {} (in flight at horizon)", self.residual);
        println!("  dispatched      {}", self.dispatched);
        println!(
            "  gpu batches     {} (mean size {:.2}, max {})",
            self.batches, self.mean_batch_size, self.max_batch_size
        );
        println!("  throughput      {:.1} req/s (virtual)", self.throughput_rps);
        println!(
            "  latency         mean {:.0} ms, p50 {:.0} ms, p95 {:.0} ms, p99 {:.0} ms",
            self.mean_latency * 1e3,
            self.p50_latency * 1e3,
            self.p95_latency * 1e3,
            self.p99_latency * 1e3
        );
        println!("  mean accuracy   {:.4}", self.mean_accuracy);
        println!(
            "  real exec       preprocess {:.2} ms, detect {:.2} ms (PJRT wall-clock)",
            self.mean_preproc_ms, self.mean_detect_ms
        );
    }
}

/// Shortest-queue fallback policy (no trained blob supplied).
pub struct ShortestQueuePolicy;

impl ServingPolicy for ShortestQueuePolicy {
    fn decide(&mut self, cluster: &EdgeCluster, _node: usize) -> Result<Action> {
        let mut best = 0;
        for j in 1..cluster.n_nodes {
            if cluster.queue_len(j) < cluster.queue_len(best) {
                best = j;
            }
        }
        Ok(Action::new(best, 1, 2))
    }
}

/// Build the serving cluster the engine runs over (default workload and
/// bandwidth traces at `opts.n_nodes` scale).
pub fn build_cluster(opts: &ServingOptions, hist_len: usize) -> EdgeCluster {
    EdgeCluster::new(
        opts.n_nodes,
        WorkloadConfig::default(),
        BandwidthConfig { n_nodes: opts.n_nodes, ..BandwidthConfig::default() },
        Profiles::default(),
        0.2,
        opts.drop_deadline,
        hist_len,
        opts.max_batch,
        opts.batch_wait,
        opts.seed,
    )
}

/// Run the serving loop with the supplied policy/compute pair and report.
pub fn run_with(
    opts: &ServingOptions,
    hist_len: usize,
    policy: &mut dyn ServingPolicy,
    compute: &mut dyn ComputeHook,
) -> Result<(EdgeCluster, ServingReport)> {
    let mut cluster = build_cluster(opts, hist_len);
    cluster.run(policy, compute, opts.duration_virtual_secs)?;
    let report =
        ServingReport::from_cluster(&cluster, opts.duration_virtual_secs, 0.0, 0.0);
    Ok((cluster, report))
}

/// Dep-free serving run: shortest-queue policy over profile-table compute.
/// The engine bench and the offline tests drive this; the PJRT server
/// (`serving::server::run_serving`) swaps in real compute and the trained
/// actor.
pub fn run_profile_serving(opts: &ServingOptions) -> Result<ServingReport> {
    let mut policy = ShortestQueuePolicy;
    let mut compute = ProfileCompute::new(Profiles::default());
    let (_, report) = run_with(opts, 5, &mut policy, &mut compute)?;
    Ok(report)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn profile_serving_report_is_conserved() {
        let opts = ServingOptions {
            duration_virtual_secs: 10.0,
            ..Default::default()
        };
        let report = run_profile_serving(&opts).unwrap();
        assert!(report.emitted > 0);
        assert!(report.completed > 0);
        assert!(report.conserved(), "{report:?}");
        assert!(report.throughput_rps > 0.0);
        assert!(report.p99_latency >= report.p50_latency);
    }

    #[test]
    fn batch_stats_count_each_execution_once() {
        let opts = ServingOptions {
            duration_virtual_secs: 15.0,
            seed: 3,
            ..Default::default()
        };
        let report = run_profile_serving(&opts).unwrap();
        assert!(report.batches > 0);
        assert!(report.mean_batch_size >= 1.0);
        assert!(report.max_batch_size <= opts.max_batch);
    }
}
