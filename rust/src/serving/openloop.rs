//! Dep-free open-loop SLO experiment — the acceptance surface for the
//! ingestion layer (`repro experiment openloop`, the
//! `serving_throughput --openloop` CLI arm, and `tests/openloop.rs`).
//!
//! For every `openloop-*` registry entry it runs the serving engine
//! twice — admission control on (the registry default) and off — under a
//! policy that pins every request to its origin node at the heaviest
//! (model, resolution). That makes the per-node overload exact: the
//! Poisson entry offers ~2x the heavy-config service capacity, so
//! without admission the queues grow until nearly every frame the GPU
//! picks up is past saving, while with admission the gate sheds the
//! infeasible fraction at the door and the admitted remainder finishes
//! inside the deadline. The headline — admission strictly beats
//! no-admission on goodput-under-SLO for the sustained-overload regime —
//! is pinned by [`assert_admission_headline`], which CI runs dep-free.
//!
//! One row per (scenario, admission) lands in
//! `results/slo_comparison.csv`: ledger columns (`emitted`, `shed`, …),
//! tail latency (p50/p99/p999 from the fixed-bucket
//! [`LatencyHistogram`]), goodput under the SLO and the shed rate.
//! Deterministic in `seed`: repeated calls yield identical rows.

use std::path::Path;

use anyhow::Result;

use crate::coordinator::cluster::ProfileCompute;
use crate::env::profiles::N_MODELS;
use crate::env::Action;
use crate::policy::{Policy, PolicyView};
use crate::scenario::Scenario;
use crate::serving::engine::{run_with, ServingOptions, ServingReport};
use crate::telemetry::slo::{LatencyHistogram, SloSummary};
use crate::util::csv::CsvWriter;
use crate::util::provenance::{write_sidecar_meta, RunMeta};

/// The open-loop registry entries the experiment sweeps.
pub const OPENLOOP_SCENARIOS: [&str; 3] =
    ["openloop-poisson", "openloop-burst", "openloop-trace"];

/// Every request stays at its origin node at the heaviest
/// (model, resolution) — the experiment's load-generating policy. With
/// routing pinned, offered-vs-capacity is a per-node constant and the
/// admission gate's origin-side delay estimate is exactly the queue the
/// request will wait in, so the on/off contrast isolates admission.
struct LocalMaxPolicy;

impl Policy for LocalMaxPolicy {
    fn name(&self) -> &str {
        "local_max"
    }

    fn decide_into(
        &mut self,
        view: &dyn PolicyView,
        out: &mut Vec<Action>,
    ) -> Result<()> {
        out.clear();
        for i in 0..view.n_nodes() {
            out.push(Action::new(i, N_MODELS - 1, 0));
        }
        Ok(())
    }
}

/// One (scenario, admission) cell of the sweep.
#[derive(Debug, Clone)]
pub struct OpenLoopRow {
    pub scenario: String,
    pub admission: bool,
    /// The SLO the goodput column counts against (the scenario's drop
    /// threshold).
    pub slo_secs: f64,
    pub report: ServingReport,
    pub slo: SloSummary,
}

/// Run the sweep: every `openloop-*` entry × admission {on, off}, each
/// conservation-checked, with SLO telemetry from the fixed-bucket
/// histogram.
pub fn openloop_rows(
    duration_virtual_secs: f64,
    seed: u64,
) -> Result<Vec<OpenLoopRow>> {
    let mut rows = Vec::new();
    for name in OPENLOOP_SCENARIOS {
        for admission in [true, false] {
            let mut scenario = Scenario::by_name(name)?;
            scenario.ingest.admission.enabled = admission;
            let slo_secs = scenario.drop_threshold;
            let opts = ServingOptions {
                scenario,
                duration_virtual_secs,
                seed,
                greedy: true,
            };
            let mut policy = LocalMaxPolicy;
            let mut compute =
                ProfileCompute::new(opts.scenario.profiles.clone());
            let (cluster, report) =
                run_with(&opts, &mut policy, &mut compute)?;
            anyhow::ensure!(
                report.conserved(),
                "{name} (admission={admission}) leaked requests"
            );
            anyhow::ensure!(
                admission || report.shed == 0,
                "{name} shed {} requests with admission disabled",
                report.shed
            );
            let mut hist = LatencyHistogram::new();
            for r in cluster.served.iter().filter(|r| !r.dropped) {
                hist.record(r.latency());
            }
            let slo = SloSummary::from_histogram(
                &hist,
                slo_secs,
                duration_virtual_secs,
                report.emitted as u64,
                report.shed as u64,
            );
            rows.push(OpenLoopRow {
                scenario: name.to_string(),
                admission,
                slo_secs,
                report,
                slo,
            });
        }
    }
    Ok(rows)
}

/// [`openloop_rows`] plus the CSV emit — the producer of
/// `results/slo_comparison.csv`.
pub fn openloop_to_csv(
    duration_virtual_secs: f64,
    seed: u64,
    path: impl AsRef<Path>,
) -> Result<Vec<OpenLoopRow>> {
    let rows = openloop_rows(duration_virtual_secs, seed)?;
    let mut w = CsvWriter::create(
        path.as_ref(),
        &[
            "scenario",
            "admission",
            "policy",
            "slo_secs",
            "emitted",
            "shed",
            "completed",
            "dropped",
            "lost_to_failure",
            "cancelled",
            "residual",
            "shed_rate",
            "p50",
            "p99",
            "p999",
            "goodput_rps",
            "throughput_rps",
        ],
    )?;
    for r in &rows {
        w.row(&[
            r.scenario.clone(),
            if r.admission { "on" } else { "off" }.to_string(),
            "local_max".to_string(),
            format!("{:.3}", r.slo_secs),
            r.report.emitted.to_string(),
            r.report.shed.to_string(),
            r.report.completed.to_string(),
            r.report.dropped.to_string(),
            r.report.lost_to_failure.to_string(),
            r.report.cancelled.to_string(),
            r.report.residual.to_string(),
            format!("{:.4}", r.slo.shed_rate),
            format!("{:.4}", r.slo.p50),
            format!("{:.4}", r.slo.p99),
            format!("{:.4}", r.slo.p999),
            format!("{:.3}", r.slo.goodput_rps),
            format!("{:.3}", r.report.throughput_rps),
        ])?;
    }
    write_sidecar_meta(
        path.as_ref(),
        &RunMeta::new(&OPENLOOP_SCENARIOS, seed, &[], duration_virtual_secs),
    )?;
    Ok(rows)
}

/// Goodput-under-SLO for a (scenario, admission) cell (0.0 when absent).
pub fn goodput_of(
    rows: &[OpenLoopRow],
    scenario: &str,
    admission: bool,
) -> f64 {
    rows.iter()
        .find(|r| r.scenario == scenario && r.admission == admission)
        .map_or(0.0, |r| r.slo.goodput_rps)
}

/// The acceptance headline: under the sustained-overload regime,
/// admission control must strictly beat no-admission on
/// goodput-under-SLO (and must actually have shed something — a gate
/// that never engages proves nothing).
pub fn assert_admission_headline(rows: &[OpenLoopRow]) -> Result<()> {
    let on = goodput_of(rows, "openloop-poisson", true);
    let off = goodput_of(rows, "openloop-poisson", false);
    anyhow::ensure!(
        on > off,
        "admission goodput {on:.3} req/s must strictly beat \
         no-admission {off:.3} req/s under openloop-poisson"
    );
    let shed = rows
        .iter()
        .find(|r| r.scenario == "openloop-poisson" && r.admission)
        .map_or(0, |r| r.report.shed);
    anyhow::ensure!(
        shed > 0,
        "the admission gate never engaged under openloop-poisson"
    );
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn sweep_is_deterministic_and_headline_holds() {
        let a = openloop_rows(12.0, 7).unwrap();
        assert_eq!(a.len(), 2 * OPENLOOP_SCENARIOS.len());
        assert_admission_headline(&a).unwrap();
        let b = openloop_rows(12.0, 7).unwrap();
        for (x, y) in a.iter().zip(&b) {
            assert_eq!(x.scenario, y.scenario);
            assert_eq!(x.admission, y.admission);
            assert_eq!(x.report.emitted, y.report.emitted);
            assert_eq!(x.report.shed, y.report.shed);
            assert_eq!(x.report.completed, y.report.completed);
            assert_eq!(x.slo, y.slo);
        }
    }

    #[test]
    fn csv_has_slo_columns() {
        let dir = std::env::temp_dir().join("ev_openloop_csv_test");
        let path = dir.join("slo_comparison.csv");
        let rows = openloop_to_csv(6.0, 3, &path).unwrap();
        assert_eq!(rows.len(), 6);
        let text = std::fs::read_to_string(&path).unwrap();
        let header = text.lines().next().unwrap();
        for col in [
            "goodput_rps",
            "shed_rate",
            "p999",
            "admission",
            "lost_to_failure",
            "cancelled",
        ] {
            assert!(header.contains(col), "missing column {col}");
        }
        assert_eq!(text.lines().count(), 7);
        assert!(
            dir.join("slo_comparison.meta.json").exists(),
            "CSV writers must drop a provenance sidecar"
        );
        let _ = std::fs::remove_dir_all(&dir);
    }
}
