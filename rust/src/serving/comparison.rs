//! Dep-free heuristic serving comparison — every registered baseline
//! (including the failure-aware [`crate::baselines::FailoverController`]
//! wrapper) on the event-driven serving engine, one conservation-checked
//! row per (scenario, method).
//!
//! This is the chaos-scenario acceptance surface: CI smoke-runs it over
//! the fault-injection registry entries (`node-churn`, `link-flap`,
//! `brownout`) without the PJRT stack, and the row set makes the headline
//! contrast auditable — `failover_shortest_queue_min` must complete
//! strictly more requests than the failure-oblivious
//! `shortest_queue_min` under `node-churn`, because only the former reads
//! the liveness surface instead of the crashed node's stale zero-delay
//! telemetry. The PJRT experiments harness
//! (`experiments::serving_comparison`) adds the trained actor on top of
//! the same sweep; columns match so downstream tooling reads either file.

use std::path::Path;

use anyhow::Result;

use crate::baselines;
use crate::scenario::Scenario;
use crate::serving::engine::{serve_scenario, ServingReport};
use crate::util::csv::CsvWriter;
use crate::util::provenance::{write_sidecar_meta, RunMeta};

/// Run every heuristic baseline under every named scenario. Each report
/// is conservation-checked (extended ledger — faults included), and
/// fault-free scenarios are additionally pinned to `lost_to_failure == 0`.
/// Deterministic in `seed`: repeated calls yield identical rows.
pub fn comparison_rows(
    scenario_names: &[&str],
    duration_virtual_secs: f64,
    seed: u64,
) -> Result<Vec<(String, String, ServingReport)>> {
    let mut rows = Vec::new();
    for name in scenario_names {
        let scenario = Scenario::by_name(name)?;
        for h in baselines::HEURISTICS {
            // same construction-seed salt as the PJRT sweep: reset mixes
            // the run seed multiplicatively, so salting here keeps the
            // pair seed-dependent without cancellation
            let mut policy = baselines::by_name(
                h,
                scenario.n_nodes,
                seed ^ 0x5EED_BA5E,
            )?;
            let report = serve_scenario(
                policy.as_mut(),
                &scenario,
                duration_virtual_secs,
                seed,
            )?;
            anyhow::ensure!(
                report.conserved(),
                "{h} leaked requests under scenario {name}"
            );
            anyhow::ensure!(
                !scenario.faults.is_empty() || report.lost_to_failure == 0,
                "{h} lost {} requests to failure under fault-free {name}",
                report.lost_to_failure
            );
            anyhow::ensure!(
                scenario.ingest.is_open() || report.shed == 0,
                "{h} shed {} requests under closed-loop {name}",
                report.shed
            );
            rows.push((name.to_string(), h.to_string(), report));
        }
    }
    Ok(rows)
}

/// [`comparison_rows`] plus the CSV emit — the dep-free producer of
/// `results/serving_comparison.csv` (column-compatible with the PJRT
/// experiments harness, minus its trained-actor rows).
pub fn comparison_to_csv(
    scenario_names: &[&str],
    duration_virtual_secs: f64,
    seed: u64,
    path: impl AsRef<Path>,
) -> Result<Vec<(String, String, ServingReport)>> {
    let rows =
        comparison_rows(scenario_names, duration_virtual_secs, seed)?;
    let mut w = CsvWriter::create(
        path.as_ref(),
        &[
            "scenario",
            "method",
            "emitted",
            "completed",
            "dropped",
            "residual",
            "lost_to_failure",
            "shed",
            "cancelled",
            "dispatched",
            "throughput_rps",
            "p95_latency",
            "mean_accuracy",
        ],
    )?;
    for (scenario, method, r) in &rows {
        w.row(&[
            scenario.clone(),
            method.clone(),
            r.emitted.to_string(),
            r.completed.to_string(),
            r.dropped.to_string(),
            r.residual.to_string(),
            r.lost_to_failure.to_string(),
            r.shed.to_string(),
            r.cancelled.to_string(),
            r.dispatched.to_string(),
            format!("{:.3}", r.throughput_rps),
            format!("{:.4}", r.p95_latency),
            format!("{:.4}", r.mean_accuracy),
        ])?;
    }
    write_sidecar_meta(
        path.as_ref(),
        &RunMeta::new(scenario_names, seed, &[], duration_virtual_secs),
    )?;
    Ok(rows)
}

/// Completed-request count for `method` under `scenario` in a row set
/// (0 when absent) — the acceptance probe CI and the chaos tests use to
/// pin "failover strictly beats the oblivious baseline under churn".
pub fn completed_of(
    rows: &[(String, String, ServingReport)],
    scenario: &str,
    method: &str,
) -> usize {
    rows.iter()
        .find(|(s, m, _)| s == scenario && m == method)
        .map_or(0, |(_, _, r)| r.completed)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn rows_cover_every_heuristic_and_are_deterministic() {
        let a = comparison_rows(&["steady"], 5.0, 7).unwrap();
        assert_eq!(a.len(), baselines::HEURISTICS.len());
        let b = comparison_rows(&["steady"], 5.0, 7).unwrap();
        for ((s1, m1, r1), (s2, m2, r2)) in a.iter().zip(&b) {
            assert_eq!((s1, m1), (s2, m2));
            assert_eq!(r1.completed, r2.completed);
            assert_eq!(r1.emitted, r2.emitted);
            assert_eq!(r1.dropped, r2.dropped);
        }
    }

    #[test]
    fn csv_has_fault_column() {
        let dir = std::env::temp_dir().join("ev_serving_comparison_test");
        let path = dir.join("serving_comparison.csv");
        let rows = comparison_to_csv(&["steady"], 4.0, 3, &path).unwrap();
        assert!(!rows.is_empty());
        let text = std::fs::read_to_string(&path).unwrap();
        let header = text.lines().next().unwrap();
        assert!(header.contains("lost_to_failure"));
        assert!(header.contains("shed"));
        assert!(header.contains("cancelled"));
        assert_eq!(text.lines().count(), rows.len() + 1);
        assert!(dir.join("serving_comparison.meta.json").exists());
        let _ = std::fs::remove_dir_all(&dir);
    }
}
