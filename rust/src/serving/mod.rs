//! Online serving runtime — the end-to-end request path: synthetic camera
//! frames, real Pallas-resize preprocessing and detector-zoo inference
//! executed through PJRT, policy-driven routing over the virtual-time edge
//! cluster, and latency/throughput reporting.

pub mod frames;
pub mod server;
pub mod zoo;

pub use frames::FrameSource;
pub use server::{run_serving, ServingOptions, ServingReport};
pub use zoo::ModelZoo;
