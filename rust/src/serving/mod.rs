//! Online serving runtime — the end-to-end request path: synthetic camera
//! frames, real Pallas-resize preprocessing and detector-zoo inference
//! executed through PJRT, policy-driven routing over the virtual-time edge
//! cluster with per-(model, res) GPU batching, and latency/throughput
//! reporting with exhaustive request accounting.
//!
//! The [`openloop`] experiment drives the engine with open-loop
//! `openloop-*` scenarios to contrast admission control on/off on
//! goodput-under-SLO (`results/slo_comparison.csv`).
//!
//! The engine (options, report, profile-table runs) is dep-free and
//! driven by the unified [`crate::policy::Policy`] trait under
//! [`crate::scenario::Scenario`] descriptors; the PJRT-backed server and
//! detector zoo sit behind the `pjrt` cargo feature. The synthetic frame
//! source is pure Rust and always available.

pub mod comparison;
pub mod engine;
pub mod frames;
pub mod openloop;
#[cfg(feature = "pjrt")]
pub mod server;
#[cfg(feature = "pjrt")]
pub mod zoo;

pub use comparison::{comparison_to_csv, completed_of};
pub use engine::{
    run_profile_serving, serve_scenario, serve_scenario_traced,
    ServingOptions, ServingReport,
};
pub use openloop::{
    assert_admission_headline, goodput_of, openloop_rows, openloop_to_csv,
    OpenLoopRow, OPENLOOP_SCENARIOS,
};
pub use frames::FrameSource;
#[cfg(feature = "pjrt")]
pub use server::run_serving;
#[cfg(feature = "pjrt")]
pub use zoo::ModelZoo;
