//! Online serving runtime — the end-to-end request path: synthetic camera
//! frames, real Pallas-resize preprocessing and detector-zoo inference
//! executed through PJRT, policy-driven routing over the virtual-time edge
//! cluster, and latency/throughput reporting.
//!
//! The PJRT-backed server and detector zoo sit behind the `pjrt` cargo
//! feature; the synthetic frame source is pure Rust and always available.

pub mod frames;
#[cfg(feature = "pjrt")]
pub mod server;
#[cfg(feature = "pjrt")]
pub mod zoo;

pub use frames::FrameSource;
#[cfg(feature = "pjrt")]
pub use server::{run_serving, ServingOptions, ServingReport};
#[cfg(feature = "pjrt")]
pub use zoo::ModelZoo;
