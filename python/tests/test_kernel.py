"""Kernel-vs-reference correctness — the core L1 signal.

Hypothesis sweeps shapes (batch, heads, seq, head_dim) and value
distributions; every case asserts the Pallas kernels match the pure-jnp
oracles in `compile.kernels.ref` to tight tolerances, for the forward
pass, the custom-VJP backward pass, and the separable-bilinear resize.
"""

import numpy as np
import pytest

import jax
import jax.numpy as jnp
from hypothesis import given, settings, strategies as st

from compile.kernels.attention import mha, _block_b, DEFAULT_BLOCK_B
from compile.kernels.resize import bilinear_matrix, resize_bilinear
from compile.kernels import ref

SETTINGS = dict(max_examples=25, deadline=None)


def rand(key, shape, scale=1.0):
    return jax.random.normal(jax.random.PRNGKey(key), shape) * scale


# ---------------------------------------------------------------------------
# attention forward
# ---------------------------------------------------------------------------


@settings(**SETTINGS)
@given(
    b=st.integers(1, 40),
    h=st.sampled_from([1, 2, 4, 8]),
    s=st.integers(2, 8),
    dh=st.sampled_from([1, 2, 4]),
    seed=st.integers(0, 2**16),
    scale=st.sampled_from([0.1, 1.0, 5.0]),
)
def test_mha_matches_ref(b, h, s, dh, seed, scale):
    q = rand(seed, (b, h, s, dh), scale)
    k = rand(seed + 1, (b, h, s, dh), scale)
    v = rand(seed + 2, (b, h, s, dh), scale)
    out = mha(q, k, v)
    expect = ref.mha_ref(q, k, v)
    np.testing.assert_allclose(out, expect, rtol=2e-5, atol=2e-6)


def test_mha_paper_dims():
    # the exact attentive-critic dims: (B*K, 8 heads, N=4 agents, head_dim 1)
    q = rand(0, (512, 8, 4, 1))
    k = rand(1, (512, 8, 4, 1))
    v = rand(2, (512, 8, 4, 1))
    np.testing.assert_allclose(
        mha(q, k, v), ref.mha_ref(q, k, v), rtol=1e-5, atol=1e-6
    )


def test_mha_softmax_rows_sum_to_one_effect():
    # constant V => output equals V rows regardless of scores
    q = rand(3, (4, 2, 4, 2), 3.0)
    k = rand(4, (4, 2, 4, 2), 3.0)
    v = jnp.ones((4, 2, 4, 2))
    np.testing.assert_allclose(mha(q, k, v), jnp.ones_like(v), rtol=1e-5)


def test_mha_extreme_logits_stable():
    # large magnitudes must not produce NaN (stable softmax)
    q = rand(5, (2, 2, 4, 2), 50.0)
    k = rand(6, (2, 2, 4, 2), 50.0)
    v = rand(7, (2, 2, 4, 2))
    out = mha(q, k, v)
    assert np.isfinite(np.asarray(out)).all()
    np.testing.assert_allclose(out, ref.mha_ref(q, k, v), rtol=1e-4, atol=1e-5)


# ---------------------------------------------------------------------------
# attention backward (custom VJP -> Pallas bwd kernel)
# ---------------------------------------------------------------------------


@settings(**SETTINGS)
@given(
    b=st.integers(1, 12),
    h=st.sampled_from([1, 2, 8]),
    s=st.integers(2, 6),
    dh=st.sampled_from([1, 2, 4]),
    seed=st.integers(0, 2**16),
)
def test_mha_grads_match_ref(b, h, s, dh, seed):
    q = rand(seed, (b, h, s, dh))
    k = rand(seed + 1, (b, h, s, dh))
    v = rand(seed + 2, (b, h, s, dh))
    do = rand(seed + 3, (b, h, s, dh))

    dq, dk, dv = jax.vjp(lambda *args: mha(*args), q, k, v)[1](do)
    eq, ek, ev = ref.mha_bwd_ref(q, k, v, do)
    np.testing.assert_allclose(dq, eq, rtol=1e-4, atol=1e-5)
    np.testing.assert_allclose(dk, ek, rtol=1e-4, atol=1e-5)
    np.testing.assert_allclose(dv, ev, rtol=1e-4, atol=1e-5)


def test_mha_grad_through_scalar_loss():
    q = rand(8, (6, 8, 4, 1))
    k = rand(9, (6, 8, 4, 1))
    v = rand(10, (6, 8, 4, 1))
    g1 = jax.grad(lambda x: jnp.sum(mha(x, k, v) ** 2))(q)
    g2 = jax.grad(lambda x: jnp.sum(ref.mha_ref(x, k, v) ** 2))(q)
    np.testing.assert_allclose(g1, g2, rtol=1e-4, atol=1e-5)


# ---------------------------------------------------------------------------
# batch blocking
# ---------------------------------------------------------------------------


@settings(**SETTINGS)
@given(b=st.integers(1, 600))
def test_block_b_divides(b):
    bb = _block_b(b)
    assert 1 <= bb <= min(b, DEFAULT_BLOCK_B)
    assert b % bb == 0


def test_blocking_invariance():
    # results identical whether the grid is 1 program or many
    q = rand(11, (8, 2, 4, 2))
    k = rand(12, (8, 2, 4, 2))
    v = rand(13, (8, 2, 4, 2))
    full = mha(q, k, v)
    per_row = jnp.concatenate(
        [mha(q[i : i + 1], k[i : i + 1], v[i : i + 1]) for i in range(8)]
    )
    np.testing.assert_allclose(full, per_row, rtol=1e-6, atol=1e-7)


# ---------------------------------------------------------------------------
# resize kernel
# ---------------------------------------------------------------------------


@settings(**SETTINGS)
@given(
    hs=st.integers(8, 40),
    ws=st.integers(8, 40),
    hd=st.integers(4, 24),
    wd=st.integers(4, 24),
    c=st.sampled_from([1, 3]),
    seed=st.integers(0, 2**16),
)
def test_resize_matches_ref(hs, ws, hd, wd, c, seed):
    img = rand(seed, (hs, ws, c))
    wy = jnp.asarray(bilinear_matrix(hd, hs))
    wx = jnp.asarray(bilinear_matrix(wd, ws))
    out = resize_bilinear(img, wy, wx)
    expect = ref.resize_ref(img, wy, wx)
    assert out.shape == (hd, wd, c)
    np.testing.assert_allclose(out, expect, rtol=1e-4, atol=1e-5)


def test_bilinear_matrix_rows_sum_to_one():
    for dst, src in [(32, 136), (92, 136), (10, 10), (20, 10)]:
        w = bilinear_matrix(dst, src)
        assert w.shape == (dst, src)
        np.testing.assert_allclose(w.sum(axis=1), np.ones(dst), rtol=1e-5)
        assert (w >= 0).all()


def test_resize_identity():
    img = rand(20, (16, 24, 3))
    wy = jnp.asarray(bilinear_matrix(16, 16))
    wx = jnp.asarray(bilinear_matrix(24, 24))
    np.testing.assert_allclose(
        resize_bilinear(img, wy, wx), img, rtol=1e-5, atol=1e-6
    )


def test_resize_preserves_constant_image():
    # row-stochastic weights => constant image stays constant
    img = jnp.full((30, 40, 3), 0.7)
    wy = jnp.asarray(bilinear_matrix(12, 30))
    wx = jnp.asarray(bilinear_matrix(16, 40))
    out = resize_bilinear(img, wy, wx)
    np.testing.assert_allclose(out, jnp.full((12, 16, 3), 0.7), rtol=1e-5)


def test_resize_paper_resolutions():
    from compile.config import RESOLUTIONS, RES_ORDER

    hs, ws = RESOLUTIONS[1080]
    img = rand(21, (hs, ws, 3))
    for res in RES_ORDER[1:]:
        hd, wd = RESOLUTIONS[res]
        wy = jnp.asarray(bilinear_matrix(hd, hs))
        wx = jnp.asarray(bilinear_matrix(wd, ws))
        out = resize_bilinear(img, wy, wx)
        expect = ref.resize_ref(img, wy, wx)
        np.testing.assert_allclose(out, expect, rtol=1e-4, atol=1e-5)
