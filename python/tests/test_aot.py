"""AOT exporter tests: HLO-text lowering of the real artifacts (fast
subset), manifest consistency, and params-blob layout."""

import json
import os

import numpy as np
import pytest

import jax
import jax.numpy as jnp
import jax.tree_util as tu

from compile import aot, model as M
from compile.config import NetConfig, PpoConfig

CFG = NetConfig()


def test_to_hlo_text_smoke():
    lowered = jax.jit(lambda x: (x * 2.0,)).lower(
        jax.ShapeDtypeStruct((3,), jnp.float32)
    )
    text = aot.to_hlo_text(lowered)
    assert "HloModule" in text
    assert "ROOT" in text


def test_actor_fwd_lowers_with_pallas_free_graph():
    params = M.init_params(jax.random.PRNGKey(0), CFG, "full")
    specs = tu.tree_map(
        lambda x: jax.ShapeDtypeStruct(x.shape, x.dtype), params["actor"]
    )
    obs = jax.ShapeDtypeStruct((CFG.n_agents, CFG.obs_dim), jnp.float32)
    mask = jax.ShapeDtypeStruct((CFG.n_agents, CFG.n_agents), jnp.float32)
    text = aot.to_hlo_text(jax.jit(M.actor_fwd).lower(specs, obs, mask))
    assert "HloModule" in text


def test_critic_fwd_lowers_with_pallas_attention():
    params = M.init_params(jax.random.PRNGKey(0), CFG, "full")
    specs = tu.tree_map(
        lambda x: jax.ShapeDtypeStruct(x.shape, x.dtype), params["critic"]
    )
    obs = jax.ShapeDtypeStruct((8, CFG.n_agents, CFG.obs_dim), jnp.float32)
    text = aot.to_hlo_text(
        jax.jit(lambda p, o: M.critic_fwd(p, o, CFG, "full")).lower(specs, obs)
    )
    # the interpret-mode Pallas kernel lowers into plain HLO (loops/dots),
    # never a Mosaic custom-call the CPU client could not run
    assert "HloModule" in text
    assert "mosaic" not in text.lower()


def test_leaf_names_deterministic_order():
    params = M.init_params(jax.random.PRNGKey(0), CFG, "full")
    names1 = [n for n, _ in aot.leaves_with_names(params)]
    names2 = [n for n, _ in aot.leaves_with_names(params)]
    assert names1 == names2
    # actor leaves come first (dict key order), as the Rust side assumes
    n_actor = len([n for n in names1 if n.startswith("actor/")])
    assert all(n.startswith("actor/") for n in names1[:n_actor])
    assert all(n.startswith("critic/") for n in names1[n_actor:])


ARTIFACTS = os.path.join(os.path.dirname(__file__), "..", "..", "artifacts")


@pytest.mark.skipif(
    not os.path.exists(os.path.join(ARTIFACTS, "manifest.json")),
    reason="artifacts not built (run `make artifacts`)",
)
class TestBuiltArtifacts:
    @pytest.fixture(scope="class")
    def manifest(self):
        with open(os.path.join(ARTIFACTS, "manifest.json")) as f:
            return json.load(f)

    def test_manifest_dims_match_config(self, manifest):
        assert manifest["net"]["n_agents"] == CFG.n_agents
        assert manifest["net"]["obs_dim"] == CFG.obs_dim
        assert manifest["net"]["minibatch"] == CFG.minibatch

    def test_all_artifact_files_exist(self, manifest):
        files = [manifest["actor_fwd"]]
        for v in manifest["variants"].values():
            files += [v["critic_fwd"], v["train_step"], v["params_init"]]
        files += [z["file"] for z in manifest["zoo"]]
        files += [p["file"] for p in manifest["preprocess"]]
        for f in files:
            assert os.path.exists(os.path.join(ARTIFACTS, f)), f

    def test_params_init_blob_sizes(self, manifest):
        for name, v in manifest["variants"].items():
            path = os.path.join(ARTIFACTS, v["params_init"])
            n = os.path.getsize(path) // 4
            assert n == v["n_elems"], name
            declared = sum(
                int(np.prod(leaf["shape"])) for leaf in v["params"]
            )
            assert declared == v["n_elems"], name

    def test_params_init_reproducible_from_seed(self, manifest):
        # re-initializing with the manifest seed reproduces the blob prefix
        seed = manifest["seed"]
        params = M.init_params(jax.random.PRNGKey(seed), CFG, "full")
        named = aot.leaves_with_names(params)
        blob = np.fromfile(
            os.path.join(
                ARTIFACTS, manifest["variants"]["full"]["params_init"]
            ),
            dtype=np.float32,
        )
        first_name, first = named[0]
        np.testing.assert_allclose(
            blob[: first.size], np.asarray(first).ravel(), rtol=1e-6
        )

    def test_hlo_artifacts_are_text(self, manifest):
        path = os.path.join(ARTIFACTS, manifest["actor_fwd"])
        head = open(path).read(200)
        assert "HloModule" in head
