"""L2 model tests: network shapes, distribution validity, masking,
variant behaviour and a train-step sanity check (loss decreases on a
fixed synthetic batch)."""

import numpy as np
import pytest

import jax
import jax.numpy as jnp
import jax.tree_util as tu
from hypothesis import given, settings, strategies as st

from compile.config import CRITIC_VARIANTS, NetConfig, PpoConfig
from compile import model as M

CFG = NetConfig()
PPO = PpoConfig()


def params_for(variant, seed=0):
    return M.init_params(jax.random.PRNGKey(seed), CFG, variant)


def rand_obs(b, seed=0):
    return jax.random.normal(
        jax.random.PRNGKey(seed), (b, CFG.n_agents, CFG.obs_dim)
    )


ZERO_MASK = jnp.zeros((CFG.n_agents, CFG.n_agents))


# ---------------------------------------------------------------------------
# actor
# ---------------------------------------------------------------------------


def test_actor_shapes_and_normalization():
    p = params_for("full")["actor"]
    obs = rand_obs(7)
    le, lm, lv = M.actor_fwd(p, obs, ZERO_MASK)
    assert le.shape == (7, CFG.n_agents, CFG.n_agents)
    assert lm.shape == (7, CFG.n_agents, CFG.n_models)
    assert lv.shape == (7, CFG.n_agents, CFG.n_res)
    for logp in (le, lm, lv):
        sums = jnp.exp(logp).sum(-1)
        np.testing.assert_allclose(sums, np.ones_like(sums), rtol=1e-5)


def test_actor_unbatched_matches_batched():
    p = params_for("full")["actor"]
    obs = rand_obs(3, seed=5)
    le_b, _, _ = M.actor_fwd(p, obs, ZERO_MASK)
    le_1, _, _ = M.actor_fwd(p, obs[1], ZERO_MASK)
    np.testing.assert_allclose(le_b[1], le_1, rtol=1e-5, atol=1e-6)


def test_actor_mask_forbids_dispatch():
    p = params_for("full")["actor"]
    mask = jnp.where(jnp.eye(CFG.n_agents) > 0, 0.0, -1e9)
    le, _, _ = M.actor_fwd(p, rand_obs(4, seed=2), mask)
    probs = jnp.exp(le)  # [B, N, E]
    for i in range(CFG.n_agents):
        np.testing.assert_allclose(probs[:, i, i], 1.0, atol=1e-5)


def test_agents_are_independent_networks():
    # perturbing agent 0's weights must not change agent 1's outputs
    p = params_for("full")["actor"]
    obs = rand_obs(2, seed=3)
    le0, _, _ = M.actor_fwd(p, obs, ZERO_MASK)
    # NB: perturb the (post-LayerNorm) head weights — uniform shifts or
    # scalings of pre-LN weights are invisible through LayerNorm by design.
    p2 = dict(p)
    noise = jax.random.normal(jax.random.PRNGKey(99), p["we"].shape[1:])
    p2["we"] = p["we"].at[0].add(noise)
    le1, _, _ = M.actor_fwd(p2, obs, ZERO_MASK)
    assert not np.allclose(le0[:, 0], le1[:, 0])
    np.testing.assert_allclose(le0[:, 1:], le1[:, 1:], rtol=1e-6)


# ---------------------------------------------------------------------------
# critic
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("variant", CRITIC_VARIANTS)
def test_critic_shapes(variant):
    p = params_for(variant)["critic"]
    vals = M.critic_fwd(p, rand_obs(6), CFG, variant)
    assert vals.shape == (6, CFG.n_agents)
    assert np.isfinite(np.asarray(vals)).all()


def test_full_critic_uses_other_agents_state():
    p = params_for("full")["critic"]
    obs = rand_obs(4, seed=9)
    v0 = M.critic_fwd(p, obs, CFG, "full")
    # change agent 3's observation: every critic's value should move
    obs2 = obs.at[:, 3].add(1.0)
    v1 = M.critic_fwd(p, obs2, CFG, "full")
    assert not np.allclose(v0[:, 0], v1[:, 0])


def test_local_critic_ignores_other_agents_state():
    p = params_for("local")["critic"]
    obs = rand_obs(4, seed=10)
    v0 = M.critic_fwd(p, obs, CFG, "local")
    obs2 = obs.at[:, 3].add(1.0)  # perturb agent 3 only
    v1 = M.critic_fwd(p, obs2, CFG, "local")
    np.testing.assert_allclose(v0[:, :3], v1[:, :3], rtol=1e-6)
    assert not np.allclose(v0[:, 3], v1[:, 3])


def test_noattn_variant_differs_from_full():
    pf = params_for("full", seed=4)
    pn = params_for("noattn", seed=4)
    assert "wq" in pf["critic"] and "wq" not in pn["critic"]


# ---------------------------------------------------------------------------
# ppo loss / train step
# ---------------------------------------------------------------------------


def synth_batch(b, seed=0):
    key = jax.random.PRNGKey(seed)
    ks = jax.random.split(key, 6)
    obs = jax.random.normal(ks[0], (b, CFG.n_agents, CFG.obs_dim))
    actions = jnp.stack(
        [
            jax.random.randint(ks[1], (b, CFG.n_agents), 0, CFG.n_agents),
            jax.random.randint(ks[2], (b, CFG.n_agents), 0, CFG.n_models),
            jax.random.randint(ks[3], (b, CFG.n_agents), 0, CFG.n_res),
        ],
        axis=-1,
    ).astype(jnp.int32)
    old_logp = -2.0 * jnp.ones((b, CFG.n_agents))
    adv = jax.random.normal(ks[4], (b, CFG.n_agents))
    ret = jax.random.normal(ks[5], (b, CFG.n_agents))
    old_val = jnp.zeros((b, CFG.n_agents))
    return obs, actions, old_logp, adv, ret, old_val, ZERO_MASK


@pytest.mark.parametrize("variant", CRITIC_VARIANTS)
def test_train_step_runs_and_is_finite(variant):
    p = params_for(variant)
    m = tu.tree_map(jnp.zeros_like, p)
    v = tu.tree_map(jnp.zeros_like, p)
    ts = jax.jit(M.make_train_step(CFG, PPO, variant))
    batch = synth_batch(32, seed=1)
    new_p, new_m, new_v, step, metrics = ts(p, m, v, 0.0, 5e-4, *batch)
    assert float(step) == 1.0
    assert np.isfinite(np.asarray(metrics)).all()
    for leaf in tu.tree_leaves(new_p):
        assert np.isfinite(np.asarray(leaf)).all()
    # parameters actually moved
    moved = any(
        not np.allclose(a, b)
        for a, b in zip(tu.tree_leaves(p), tu.tree_leaves(new_p))
    )
    assert moved


def test_value_loss_decreases_on_fixed_batch():
    # repeated updates on one batch must fit the value targets
    variant = "full"
    p = params_for(variant)
    m = tu.tree_map(jnp.zeros_like, p)
    v = tu.tree_map(jnp.zeros_like, p)
    ts = jax.jit(M.make_train_step(CFG, PPO, variant))
    batch = synth_batch(64, seed=2)
    step = 0.0
    losses = []
    for _ in range(30):
        p, m, v, step, metrics = ts(p, m, v, step, 3e-3, *batch)
        losses.append(float(metrics[2]))
    assert losses[-1] < losses[0] * 0.7, f"value loss did not drop: {losses[:3]} -> {losses[-3:]}"


def test_entropy_bounds():
    p = params_for("full")
    batch = synth_batch(16, seed=3)
    _, aux = M.ppo_loss(p, batch, CFG, PPO, "full")
    ent = float(aux[2])
    max_ent = np.log(CFG.n_agents) + np.log(CFG.n_models) + np.log(CFG.n_res)
    assert 0.0 < ent <= max_ent + 1e-5


@settings(max_examples=10, deadline=None)
@given(seed=st.integers(0, 1000))
def test_ppo_loss_finite_for_random_batches(seed):
    p = params_for("full", seed=seed % 5)
    batch = synth_batch(8, seed=seed)
    total, aux = M.ppo_loss(p, batch, CFG, PPO, "full")
    assert np.isfinite(float(total))
    assert all(np.isfinite(float(a)) for a in aux)


# ---------------------------------------------------------------------------
# detector zoo
# ---------------------------------------------------------------------------


def test_detector_outputs():
    from compile.config import RESOLUTIONS

    for s in range(4):
        det = M.make_detector(s)
        h, w = RESOLUTIONS[240]
        frame = jax.random.uniform(jax.random.PRNGKey(s), (h, w, 3))
        scores = det(frame)
        assert scores.shape == (M.N_CLASSES,)
        assert ((scores >= 0) & (scores <= 1)).all()


def test_detector_sizes_increase_compute():
    # deeper zoo entries have more conv layers (proxy for Table III ordering)
    chs = [M.ZOO_SPECS[i] for i in range(4)]
    assert all(chs[i][1] <= chs[i + 1][1] for i in range(3))
    assert all(chs[i][0] <= chs[i + 1][0] for i in range(3))
