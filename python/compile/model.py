"""L2 — the EdgeVision MARL networks and fused PPO train step, in JAX.

Implements Section V of the paper:
  * per-agent actor MLPs (local state -> three categorical heads (e, m, v)),
  * per-agent attentive critics (global state -> value) with the Pallas
    multi-head attention kernel from `kernels.attention` as the
    knowledge-distillation stage (Eqs. 12-14),
  * PPO-clip policy objective (Eq. 18), clipped value loss (Eq. 19), GAE is
    computed Rust-side; the fused `train_step` consumes (obs, actions,
    old_logp, adv, ret, old_val) minibatches and performs one Adam update.

Everything is functional (params as pytrees of f32 arrays) so the whole
thing lowers to a single HLO module per artifact. Parameters are *stacked
over agents* (leading dim N): each agent owns an independent network, and
the stacked einsum formulation evaluates all N agents in one call.

Critic variants (paper Section VI-D ablations + IPPO baseline):
  * "full"   — embeddings of all agents -> 8-head Pallas attention -> MLP.
  * "noattn" — embeddings of all agents concatenated directly -> MLP
               ("W/O Attention": undifferentiated view of everyone).
  * "local"  — own observation only ("W/O Other's State"; also the IPPO
               critic, which has no access to other agents during training).
"""

import functools

import jax
import jax.numpy as jnp
import numpy as np

from .config import NetConfig, PpoConfig
from .kernels.attention import mha

# ---------------------------------------------------------------------------
# primitives
# ---------------------------------------------------------------------------


def layer_norm(x, g, b, eps=1e-5):
    mu = jnp.mean(x, axis=-1, keepdims=True)
    var = jnp.var(x, axis=-1, keepdims=True)
    return (x - mu) / jnp.sqrt(var + eps) * g + b


def _dense_init(key, fan_in, shape, scale=None):
    """Orthogonal-ish init: normal scaled by sqrt(2/fan_in) (He) by default."""
    if scale is None:
        scale = np.sqrt(2.0 / fan_in)
    return jax.random.normal(key, shape, dtype=jnp.float32) * scale


# ---------------------------------------------------------------------------
# actor
# ---------------------------------------------------------------------------


def init_actor_params(key, cfg: NetConfig):
    """Per-agent actor MLP params, stacked over agents (leading dim N)."""
    n, d, h = cfg.n_agents, cfg.obs_dim, cfg.hidden
    ks = jax.random.split(key, 5)
    heads = {
        "we": _dense_init(ks[2], h, (n, h, cfg.n_agents), scale=0.01),
        "wm": _dense_init(ks[3], h, (n, h, cfg.n_models), scale=0.01),
        "wv": _dense_init(ks[4], h, (n, h, cfg.n_res), scale=0.01),
    }
    return {
        "w1": _dense_init(ks[0], d, (n, d, h)),
        "b1": jnp.zeros((n, h)),
        "g1": jnp.ones((n, h)),
        "bb1": jnp.zeros((n, h)),
        "w2": _dense_init(ks[1], h, (n, h, h)),
        "b2": jnp.zeros((n, h)),
        "g2": jnp.ones((n, h)),
        "bb2": jnp.zeros((n, h)),
        **heads,
        "be": jnp.zeros((n, cfg.n_agents)),
        "bm": jnp.zeros((n, cfg.n_models)),
        "bv": jnp.zeros((n, cfg.n_res)),
    }


def actor_fwd(p, obs, mask_e):
    """All agents' actor forward.

    Args:
      p:      stacked actor params (leading dim N).
      obs:    [B, N, D] local states (or [N, D]; a batch dim is added).
      mask_e: [N, E] additive mask on the dispatch-target head logits
              (0 = allowed, -1e9 = forbidden; used by Local-PPO).
    Returns:
      (logp_e [B,N,E], logp_m [B,N,M], logp_v [B,N,V]) log-probabilities.
    """
    squeeze = obs.ndim == 2
    if squeeze:
        obs = obs[None]
    h = jnp.einsum("bnd,ndh->bnh", obs, p["w1"]) + p["b1"]
    h = jax.nn.relu(layer_norm(h, p["g1"], p["bb1"]))
    h = jnp.einsum("bnh,nhk->bnk", h, p["w2"]) + p["b2"]
    h = jax.nn.relu(layer_norm(h, p["g2"], p["bb2"]))
    le = jnp.einsum("bnh,nhe->bne", h, p["we"]) + p["be"] + mask_e[None]
    lm = jnp.einsum("bnh,nhm->bnm", h, p["wm"]) + p["bm"]
    lv = jnp.einsum("bnh,nhv->bnv", h, p["wv"]) + p["bv"]
    out = tuple(jax.nn.log_softmax(x, axis=-1) for x in (le, lm, lv))
    if squeeze:
        out = tuple(x[0] for x in out)
    return out


# ---------------------------------------------------------------------------
# critic
# ---------------------------------------------------------------------------


def init_critic_params(key, cfg: NetConfig, variant: str):
    """Per-agent critic params, stacked over critics (leading dim N = K)."""
    n, d, h, e = cfg.n_agents, cfg.obs_dim, cfg.hidden, cfg.embed
    ks = jax.random.split(key, 8)
    if variant == "local":
        head_in = e
    else:
        head_in = n * e
    p = {
        "head_w1": _dense_init(ks[0], head_in, (n, head_in, h)),
        "head_b1": jnp.zeros((n, h)),
        "head_g1": jnp.ones((n, h)),
        "head_bb1": jnp.zeros((n, h)),
        "head_w2": _dense_init(ks[1], h, (n, h, h)),
        "head_b2": jnp.zeros((n, h)),
        "head_g2": jnp.ones((n, h)),
        "head_bb2": jnp.zeros((n, h)),
        "head_w3": _dense_init(ks[2], h, (n, h, 1), scale=1.0 / np.sqrt(h)),
        "head_b3": jnp.zeros((n, 1)),
    }
    if variant == "local":
        # one embedding net per critic (its own obs only)
        p["emb_w"] = _dense_init(ks[3], d, (n, d, e))
        p["emb_b"] = jnp.zeros((n, e))
    else:
        # critic k owns an embedding net Theta_{k,i} per observed agent i
        p["emb_w"] = _dense_init(ks[3], d, (n, n, d, e))
        p["emb_b"] = jnp.zeros((n, n, e))
    if variant == "full":
        p["wq"] = _dense_init(ks[4], e, (n, e, e))
        p["wk"] = _dense_init(ks[5], e, (n, e, e))
        p["wv"] = _dense_init(ks[6], e, (n, e, e))
        p["wo"] = _dense_init(ks[7], e, (n, e, e))
    return p


def _critic_head(p, z):
    """z: [B, K, head_in] -> values [B, K]."""
    h = jnp.einsum("bki,kih->bkh", z, p["head_w1"]) + p["head_b1"]
    h = jax.nn.relu(layer_norm(h, p["head_g1"], p["head_bb1"]))
    h = jnp.einsum("bkh,khj->bkj", h, p["head_w2"]) + p["head_b2"]
    h = jax.nn.relu(layer_norm(h, p["head_g2"], p["head_bb2"]))
    v = jnp.einsum("bkh,kho->bko", h, p["head_w3"]) + p["head_b3"]
    return v[..., 0]


def critic_fwd(p, obs, cfg: NetConfig, variant: str):
    """All critics' value predictions.

    Args:
      p:   stacked critic params (leading critic dim K = N).
      obs: [B, N, D] — every agent's local state (the global state, Eq. 7).
    Returns:
      values [B, N] — critic k's value prediction (for agent k).
    """
    squeeze = obs.ndim == 2
    if squeeze:
        obs = obs[None]
    b, n, _ = obs.shape
    e = cfg.embed
    if variant == "local":
        # e_k = Theta_k(o_k); head on own embedding only
        emb = jnp.einsum("bkd,kde->bke", obs, p["emb_w"]) + p["emb_b"]
        emb = jax.nn.relu(emb)
        v = _critic_head(p, emb)
        return v[0] if squeeze else v

    # critic k embeds every agent i with its own Theta_{k,i} (Eq. 12)
    emb = jnp.einsum("bid,kide->bkie", obs, p["emb_w"]) + p["emb_b"]
    emb = jax.nn.relu(emb)  # [B, K, N, E]

    if variant == "full":
        # 8-head attention over the N embeddings, per critic (Eq. 13);
        # this is the Pallas kernel — it lowers into the same HLO module.
        hd = cfg.head_dim
        q = jnp.einsum("bkie,kef->bkif", emb, p["wq"])
        k_ = jnp.einsum("bkie,kef->bkif", emb, p["wk"])
        v_ = jnp.einsum("bkie,kef->bkif", emb, p["wv"])

        def split(x):  # [B,K,N,E] -> [B*K, H, N, hd]
            return (
                x.reshape(b * n, n, cfg.heads, hd).transpose(0, 2, 1, 3)
            )

        o = mha(split(q), split(k_), split(v_))  # [B*K, H, N, hd]
        o = o.transpose(0, 2, 1, 3).reshape(b, n, n, e)
        psi = jnp.einsum("bkie,kef->bkif", o, p["wo"])  # (Eq. 13 outputs)
    else:  # "noattn": undifferentiated concatenation of all embeddings
        psi = emb

    z = psi.reshape(b, n, n * e)  # concat psi_1..psi_N (Eq. 14 input)
    v = _critic_head(p, z)
    return v[0] if squeeze else v


# ---------------------------------------------------------------------------
# PPO train step (fused: losses + grads + global-norm clip + Adam)
# ---------------------------------------------------------------------------


def init_params(key, cfg: NetConfig, variant: str):
    ka, kc = jax.random.split(key)
    return {
        "actor": init_actor_params(ka, cfg),
        "critic": init_critic_params(kc, cfg, variant),
    }


def _gather(logp, idx):
    """logp: [B, N, A], idx: [B, N] int32 -> [B, N]."""
    return jnp.take_along_axis(logp, idx[..., None], axis=-1)[..., 0]


def _entropy(logp):
    """Categorical entropy per [B, N] element from log-probs [B, N, A]."""
    return -jnp.sum(jnp.exp(logp) * logp, axis=-1)


def ppo_loss(params, batch, cfg: NetConfig, ppo: PpoConfig, variant: str):
    """PPO-clip objective (Eq. 18) + clipped value loss (Eq. 19) + entropy."""
    obs, actions, old_logp, adv, ret, old_val, mask_e = batch
    logp_e, logp_m, logp_v = actor_fwd(params["actor"], obs, mask_e)
    lp = (
        _gather(logp_e, actions[..., 0])
        + _gather(logp_m, actions[..., 1])
        + _gather(logp_v, actions[..., 2])
    )  # joint log-prob of the factored action, [B, N]

    # advantage normalization over the minibatch (standard PPO practice)
    adv_n = (adv - jnp.mean(adv)) / (jnp.std(adv) + 1e-8)

    ratio = jnp.exp(lp - old_logp)  # eta in Eq. (18)
    s1 = ratio * adv_n
    s2 = jnp.clip(ratio, 1.0 - ppo.clip_eps, 1.0 + ppo.clip_eps) * adv_n
    policy_loss = -jnp.mean(jnp.minimum(s1, s2))

    ent = jnp.mean(_entropy(logp_e) + _entropy(logp_m) + _entropy(logp_v))

    values = critic_fwd(params["critic"], obs, cfg, variant)  # [B, N]
    v_clip = old_val + jnp.clip(
        values - old_val, -ppo.value_clip_eps, ppo.value_clip_eps
    )
    v_loss = jnp.mean(
        jnp.maximum((values - ret) ** 2, (v_clip - ret) ** 2)
    )  # Eq. (19)

    total = policy_loss - ppo.entropy_coef * ent + ppo.value_coef * v_loss
    approx_kl = jnp.mean(old_logp - lp)
    clip_frac = jnp.mean(
        (jnp.abs(ratio - 1.0) > ppo.clip_eps).astype(jnp.float32)
    )
    aux = (policy_loss, v_loss, ent, approx_kl, clip_frac, jnp.mean(values))
    return total, aux


def make_train_step(cfg: NetConfig, ppo: PpoConfig, variant: str):
    """Builds the fused train step for one critic variant.

    Signature (all f32 unless noted):
      train_step(params, adam_m, adam_v, step, lr,
                 obs [B,N,D], actions [B,N,3] i32, old_logp [B,N],
                 adv [B,N], ret [B,N], old_val [B,N], mask_e [N,E])
        -> (params', adam_m', adam_v', step', metrics [8])

    metrics = [total, policy_loss, value_loss, entropy, approx_kl,
               clip_frac, value_mean, grad_norm].
    """

    def train_step(params, m, v, step, lr, obs, actions, old_logp, adv, ret,
                   old_val, mask_e):
        batch = (obs, actions, old_logp, adv, ret, old_val, mask_e)
        (total, aux), grads = jax.value_and_grad(ppo_loss, has_aux=True)(
            params, batch, cfg, ppo, variant
        )
        # per-subtree grad-norm clip: the critic's (initially large) value
        # errors must not starve the actor of its gradient budget
        def clip_subtree(g):
            leaves = jax.tree_util.tree_leaves(g)
            norm = jnp.sqrt(sum(jnp.sum(x * x) for x in leaves))
            coef = jnp.minimum(1.0, ppo.max_grad_norm / (norm + 1e-8))
            return jax.tree_util.tree_map(lambda x: x * coef, g), norm

        grads_a, norm_a = clip_subtree(grads["actor"])
        grads_c, norm_c = clip_subtree(grads["critic"])
        grads = {"actor": grads_a, "critic": grads_c}
        gnorm = jnp.sqrt(norm_a**2 + norm_c**2)

        step1 = step + 1.0
        bc1 = 1.0 - ppo.adam_b1**step1
        bc2 = 1.0 - ppo.adam_b2**step1

        def upd(p_, g_, m_, v_):
            m2 = ppo.adam_b1 * m_ + (1.0 - ppo.adam_b1) * g_
            v2 = ppo.adam_b2 * v_ + (1.0 - ppo.adam_b2) * g_ * g_
            p2 = p_ - lr * (m2 / bc1) / (jnp.sqrt(v2 / bc2) + ppo.adam_eps)
            return p2, m2, v2

        flat = jax.tree_util.tree_map(upd, params, grads, m, v)
        new_p = jax.tree_util.tree_map(lambda t: t[0], flat,
                                       is_leaf=lambda t: isinstance(t, tuple))
        new_m = jax.tree_util.tree_map(lambda t: t[1], flat,
                                       is_leaf=lambda t: isinstance(t, tuple))
        new_v = jax.tree_util.tree_map(lambda t: t[2], flat,
                                       is_leaf=lambda t: isinstance(t, tuple))
        metrics = jnp.stack(
            [total, aux[0], aux[1], aux[2], aux[3], aux[4], aux[5], gnorm]
        )
        return new_p, new_m, new_v, step1, metrics

    return train_step


# ---------------------------------------------------------------------------
# detector model zoo (serving-path stand-ins for the paper's four models)
# ---------------------------------------------------------------------------

# (channels, depth) per zoo size; deeper/wider == slower + "more accurate",
# mirroring the ordering of Tables II/III.
ZOO_SPECS = [(8, 2), (12, 3), (20, 4), (28, 5)]
N_CLASSES = 16


def make_detector(size_idx: int, seed: int = 1234):
    """A small conv detector with baked-in (constant) weights.

    Returns fn: frame [H, W, 3] f32 -> scores [N_CLASSES]. Weights are
    closure constants so the AOT artifact needs no parameter plumbing;
    the zoo exists to put *real tensor compute* on the serving path, not
    to be trained.
    """
    ch, depth = ZOO_SPECS[size_idx]
    rng = np.random.default_rng(seed + size_idx)
    kernels = []
    cin = 3
    for _ in range(depth):
        k = rng.normal(0, np.sqrt(2.0 / (9 * cin)), (3, 3, cin, ch)).astype(
            np.float32
        )
        kernels.append(jnp.asarray(k))
        cin = ch
    w_out = jnp.asarray(
        rng.normal(0, np.sqrt(1.0 / ch), (ch, N_CLASSES)).astype(np.float32)
    )

    def detector(frame):
        x = frame[None]  # NHWC
        for k in kernels:
            x = jax.lax.conv_general_dilated(
                x, k, window_strides=(2, 2), padding="SAME",
                dimension_numbers=("NHWC", "HWIO", "NHWC"),
            )
            x = jax.nn.relu(x)
        pooled = jnp.mean(x, axis=(1, 2))  # [1, ch]
        return jax.nn.sigmoid(pooled @ w_out)[0]

    return detector
