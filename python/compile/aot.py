"""AOT exporter — lowers every L2/L1 computation to HLO *text* artifacts.

Run once at build time (`make artifacts`); Python never appears on the
request or training path afterwards. The Rust coordinator loads the
artifacts via `xla::HloModuleProto::from_text_file` + PJRT-CPU.

Interchange format is HLO text, NOT `.serialize()`: jax >= 0.5 emits
HloModuleProtos with 64-bit instruction ids which xla_extension 0.5.1
rejects (`proto.id() <= INT_MAX`); the text parser reassigns ids and
round-trips cleanly. Everything is lowered with `return_tuple=True` and
unwrapped tuple-wise on the Rust side.

Artifacts (see DESIGN.md §3):
  actor_fwd.hlo.txt                stacked-agent actor forward
  critic_fwd_{variant}.hlo.txt     stacked-agent critic forward (3 variants)
  train_step_{variant}.hlo.txt     fused PPO minibatch update (3 variants)
  detector_{s}_{res}.hlo.txt       model-zoo CNN forward (4 sizes x 5 res)
  preprocess_{res}.hlo.txt         Pallas bilinear resize 1080 -> res
  params_init_{variant}.bin        initial parameters, f32 LE, flatten order
  manifest.json                    shapes/orders/dims contract for Rust
"""

import argparse
import json
import os

import numpy as np

import jax
import jax.numpy as jnp
import jax.tree_util as tu
from jax._src.lib import xla_client as xc

from .config import (
    CRITIC_VARIANTS,
    MODEL_NAMES,
    RES_ORDER,
    RESOLUTIONS,
    NetConfig,
    PpoConfig,
)
from . import model as M
from .kernels.resize import bilinear_matrix, resize_bilinear


def to_hlo_text(lowered) -> str:
    """StableHLO -> XlaComputation -> HLO text (the 0.5.1-safe format)."""
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def _spec_of(x):
    return jax.ShapeDtypeStruct(x.shape, x.dtype)


def _leaf_name(path) -> str:
    return "/".join(str(getattr(p, "key", p)) for p in path)


def leaves_with_names(tree):
    flat, _ = tu.tree_flatten_with_path(tree)
    return [(_leaf_name(path), leaf) for path, leaf in flat]


def write_artifact(outdir, name, lowered):
    text = to_hlo_text(lowered)
    path = os.path.join(outdir, name)
    with open(path, "w") as f:
        f.write(text)
    print(f"  wrote {name} ({len(text) // 1024} KiB)")
    return name


def export_rl(outdir, cfg: NetConfig, ppo: PpoConfig, seed: int):
    """Lower actor/critic/train_step for all critic variants; init params."""
    manifest_variants = {}
    n, d = cfg.n_agents, cfg.obs_dim
    mask_spec = jax.ShapeDtypeStruct((n, n), jnp.float32)

    # --- actor forward (shared by every variant) -------------------------
    params_full = M.init_params(jax.random.PRNGKey(seed), cfg, "full")
    actor_specs = tu.tree_map(_spec_of, params_full["actor"])
    obs_step = jax.ShapeDtypeStruct((n, d), jnp.float32)
    lowered = jax.jit(M.actor_fwd).lower(actor_specs, obs_step, mask_spec)
    actor_name = write_artifact(outdir, "actor_fwd.hlo.txt", lowered)
    actor_leaves = [
        {"name": nm, "shape": list(x.shape)}
        for nm, x in leaves_with_names(params_full["actor"])
    ]

    # batched-rollout actor: identical network, leading env dim E, so one
    # PJRT execution (and one observation upload) serves E simulators per
    # slot during training rollouts (the mask broadcasts over E)
    actor_batched_name = None
    if cfg.rollout_envs > 1:
        obs_roll = jax.ShapeDtypeStruct(
            (cfg.rollout_envs, n, d), jnp.float32
        )
        lowered = jax.jit(M.actor_fwd).lower(actor_specs, obs_roll, mask_spec)
        actor_batched_name = write_artifact(
            outdir, "actor_fwd_batched.hlo.txt", lowered
        )

    # --- per-variant critic forward + train step -------------------------
    for variant in CRITIC_VARIANTS:
        params = M.init_params(jax.random.PRNGKey(seed), cfg, variant)
        pspecs = tu.tree_map(_spec_of, params)

        obs_cb = jax.ShapeDtypeStruct((cfg.critic_batch, n, d), jnp.float32)
        lowered = jax.jit(
            lambda p, o, _v=variant: M.critic_fwd(p, o, cfg, _v)
        ).lower(pspecs["critic"], obs_cb)
        critic_name = write_artifact(
            outdir, f"critic_fwd_{variant}.hlo.txt", lowered
        )

        b = cfg.minibatch
        f32 = jnp.float32
        batch_specs = dict(
            obs=jax.ShapeDtypeStruct((b, n, d), f32),
            actions=jax.ShapeDtypeStruct((b, n, 3), jnp.int32),
            old_logp=jax.ShapeDtypeStruct((b, n), f32),
            adv=jax.ShapeDtypeStruct((b, n), f32),
            ret=jax.ShapeDtypeStruct((b, n), f32),
            old_val=jax.ShapeDtypeStruct((b, n), f32),
        )
        scalar = jax.ShapeDtypeStruct((), f32)
        ts = M.make_train_step(cfg, PpoConfig(), variant)
        lowered = jax.jit(ts).lower(
            pspecs, pspecs, pspecs, scalar, scalar,
            batch_specs["obs"], batch_specs["actions"],
            batch_specs["old_logp"], batch_specs["adv"],
            batch_specs["ret"], batch_specs["old_val"], mask_spec,
        )
        ts_name = write_artifact(
            outdir, f"train_step_{variant}.hlo.txt", lowered
        )

        # initial parameter dump, flatten order == HLO parameter order
        named = leaves_with_names(params)
        blob = np.concatenate(
            [np.asarray(x, dtype=np.float32).ravel() for _, x in named]
        )
        bin_name = f"params_init_{variant}.bin"
        blob.tofile(os.path.join(outdir, bin_name))
        print(f"  wrote {bin_name} ({blob.size} f32 elems)")

        manifest_variants[variant] = {
            "params": [
                {"name": nm, "shape": list(x.shape)} for nm, x in named
            ],
            "n_elems": int(blob.size),
            "params_init": bin_name,
            "critic_fwd": critic_name,
            "train_step": ts_name,
            "train_step_metrics": [
                "total", "policy_loss", "value_loss", "entropy",
                "approx_kl", "clip_frac", "value_mean", "grad_norm",
            ],
        }

    out = {
        "actor_fwd": actor_name,
        "actor_params": actor_leaves,
        "variants": manifest_variants,
    }
    if actor_batched_name:
        out["actor_fwd_batched"] = actor_batched_name
    return out


def export_zoo(outdir, seed: int):
    """Lower the 4-size detector zoo at every resolution + preprocessors."""
    zoo = []
    for s in range(len(M.ZOO_SPECS)):
        det = M.make_detector(s, seed=seed)
        for res in RES_ORDER:
            h, w = RESOLUTIONS[res]
            spec = jax.ShapeDtypeStruct((h, w, 3), jnp.float32)
            lowered = jax.jit(det).lower(spec)
            name = write_artifact(outdir, f"detector_s{s}_{res}.hlo.txt",
                                  lowered)
            zoo.append({
                "model": s, "model_name": MODEL_NAMES[s], "res": res,
                "file": name, "input_shape": [h, w, 3],
                "n_scores": M.N_CLASSES,
            })

    pre = []
    hs, ws = RESOLUTIONS[RES_ORDER[0]]
    for res in RES_ORDER[1:]:
        hd, wd = RESOLUTIONS[res]
        wy = jnp.asarray(bilinear_matrix(hd, hs))
        wx = jnp.asarray(bilinear_matrix(wd, ws))

        def preprocess(img, _wy=wy, _wx=wx):
            # the Pallas separable-bilinear kernel; weights are constants
            return resize_bilinear(img, _wy, _wx)

        spec = jax.ShapeDtypeStruct((hs, ws, 3), jnp.float32)
        lowered = jax.jit(preprocess).lower(spec)
        name = write_artifact(outdir, f"preprocess_{res}.hlo.txt", lowered)
        pre.append({
            "res": res, "file": name,
            "input_shape": [hs, ws, 3], "output_shape": [hd, wd, 3],
        })
    return {"zoo": zoo, "preprocess": pre}


def main():
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--outdir", default="../artifacts")
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--skip-zoo", action="store_true",
                    help="RL artifacts only (fast dev cycle)")
    args = ap.parse_args()
    os.makedirs(args.outdir, exist_ok=True)

    cfg = NetConfig()
    ppo = PpoConfig()
    manifest = {
        "version": 1,
        "net": cfg.asdict(),
        "ppo": ppo.asdict(),
        "res_order": RES_ORDER,
        "resolutions": {str(r): list(RESOLUTIONS[r]) for r in RES_ORDER},
        "model_names": MODEL_NAMES,
        "seed": args.seed,
    }

    print("[aot] RL artifacts")
    manifest.update(export_rl(args.outdir, cfg, ppo, args.seed))
    if not args.skip_zoo:
        print("[aot] detector zoo + preprocess artifacts")
        manifest.update(export_zoo(args.outdir, args.seed))
    else:
        manifest.update({"zoo": [], "preprocess": []})

    with open(os.path.join(args.outdir, "manifest.json"), "w") as f:
        json.dump(manifest, f, indent=1)
    print(f"[aot] manifest.json written to {args.outdir}")


if __name__ == "__main__":
    main()
