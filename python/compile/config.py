"""Network / system dimensions shared by the L2 model and the AOT exporter.

These mirror the paper's experimental setting (Section VI-A):
  * N = 4 homogeneous edge nodes,
  * 4 DNN detector models per node (Table II/III),
  * 5 candidate resolutions {1080, 720, 480, 360, 240}P,
  * actor/critic MLPs with two 128-neuron hidden layers (ReLU + LayerNorm),
  * per-agent embedding nets with 8 neurons, 8-head attentive critic.

The Rust coordinator reads the same numbers from artifacts/manifest.json, so
this file is the single source of truth for every tensor shape that crosses
the Rust <-> HLO boundary.
"""

from dataclasses import dataclass, field


# (height, width) per resolution, 1/8-scale of the real pixel grids so the
# CPU-PJRT detector zoo stays fast. Aspect ratio is preserved (~16:9) and
# every dim is even to keep the conv stack's stride-2 pyramid clean.
RESOLUTIONS = {
    1080: (136, 240),
    720: (92, 160),
    480: (60, 108),
    360: (44, 80),
    240: (32, 56),
}

# Order used by the `v` (resolution) action head: index 0 = 1080P ... 4 = 240P.
RES_ORDER = [1080, 720, 480, 360, 240]

# Detector zoo stand-ins for the paper's four models, ordered exactly like
# Tables II/III: index 0 = fasterrcnn_mobilenet_320 ... 3 = maskrcnn_resnet50.
MODEL_NAMES = [
    "fasterrcnn_mobilenet_320",
    "fasterrcnn_mobilenet",
    "retinanet_resnet50",
    "maskrcnn_resnet50",
]


@dataclass(frozen=True)
class NetConfig:
    """Shapes of the MARL networks (paper Section V-B / VI-A)."""

    n_agents: int = 4          # N edge nodes == agents
    hist_len: int = 5          # arrival-rate history window in the local state
    n_models: int = 4          # |M|
    n_res: int = 5             # |V|
    hidden: int = 128          # actor/critic hidden width
    embed: int = 8             # per-agent embedding width (paper: 8 neurons)
    heads: int = 8             # attention heads (paper: 8)
    minibatch: int = 256       # PPO minibatch size baked into train_step
    critic_batch: int = 128    # batch dim baked into the critic_fwd artifact
    rollout_envs: int = 4      # env count E baked into actor_fwd_batched

    @property
    def obs_dim(self) -> int:
        # o_i = (lambda history, l_i, q_ij for j != i, b_ij for j != i); Eq. (6)
        return self.hist_len + 1 + 2 * (self.n_agents - 1)

    @property
    def head_dim(self) -> int:
        assert self.embed % self.heads == 0
        return self.embed // self.heads

    def asdict(self) -> dict:
        return {
            "n_agents": self.n_agents,
            "hist_len": self.hist_len,
            "n_models": self.n_models,
            "n_res": self.n_res,
            "hidden": self.hidden,
            "embed": self.embed,
            "heads": self.heads,
            "minibatch": self.minibatch,
            "critic_batch": self.critic_batch,
            "rollout_envs": self.rollout_envs,
            "obs_dim": self.obs_dim,
        }


# PPO hyper-parameters baked into the train_step artifact (paper VI-A):
# clip eps 0.2, entropy coefficient 0.01; value-loss clip mirrors Eq. (19).
@dataclass(frozen=True)
class PpoConfig:
    clip_eps: float = 0.2
    value_clip_eps: float = 0.2
    entropy_coef: float = 0.01
    value_coef: float = 0.5
    max_grad_norm: float = 0.5
    adam_b1: float = 0.9
    adam_b2: float = 0.999
    adam_eps: float = 1e-5

    def asdict(self) -> dict:
        return {
            "clip_eps": self.clip_eps,
            "value_clip_eps": self.value_clip_eps,
            "entropy_coef": self.entropy_coef,
            "value_coef": self.value_coef,
            "max_grad_norm": self.max_grad_norm,
            "adam_b1": self.adam_b1,
            "adam_b2": self.adam_b2,
            "adam_eps": self.adam_eps,
        }


CRITIC_VARIANTS = ("full", "noattn", "local")
