"""Pure-jnp correctness oracles for the Pallas kernels.

These are the ground truth the pytest/hypothesis suite compares the
Pallas implementations against (kernel vs ref allclose). Keep them
boring and obviously correct.
"""

import jax
import jax.numpy as jnp


def mha_ref(q: jax.Array, k: jax.Array, v: jax.Array) -> jax.Array:
    """Multi-head scaled-dot-product attention, reference implementation.

    Args:
      q, k, v: [batch, heads, seq, head_dim] float32.
    Returns:
      [batch, heads, seq, head_dim] attention output.
    """
    dh = q.shape[-1]
    scores = jnp.einsum("bhqd,bhkd->bhqk", q, k) / jnp.sqrt(jnp.float32(dh))
    probs = jax.nn.softmax(scores, axis=-1)
    return jnp.einsum("bhqk,bhkd->bhqd", probs, v)


def mha_bwd_ref(q, k, v, do):
    """Reference gradients of mha_ref wrt (q, k, v) given output cotangent."""

    def f(q_, k_, v_):
        return mha_ref(q_, k_, v_)

    _, vjp = jax.vjp(f, q, k, v)
    return vjp(do)


def resize_ref(img: jax.Array, wy: jax.Array, wx: jax.Array) -> jax.Array:
    """Separable resize as two contractions: out[:,:,c] = wy @ img[:,:,c] @ wx^T.

    Args:
      img: [H_src, W_src, C] float32.
      wy:  [H_dst, H_src] row-interpolation weights.
      wx:  [W_dst, W_src] column-interpolation weights.
    Returns:
      [H_dst, W_dst, C] resized image.
    """
    return jnp.einsum("yh,hwc,xw->yxc", wy, img, wx)
