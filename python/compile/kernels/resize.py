"""Pallas bilinear frame-resize kernel — the preprocessing hot-spot (L1).

The paper's pipeline step (1) downsizes each 1080P frame to the resolution
`v` chosen by the agent before local inference or dispatch. On the paper's
GPU testbed this is a CUDA resize; the TPU rethink (DESIGN.md
§Hardware-Adaptation) expresses separable bilinear interpolation as two
dense contractions so it runs on the MXU instead of gather units:

    out[:, :, c] = Wy @ img[:, :, c] @ Wx^T

where Wy [H_dst, H_src] and Wx [W_dst, W_src] are the (precomputed,
constant per resolution pair) interpolation weight matrices. The grid
tiles the channel axis; each program keeps one image plane plus both
weight matrices in VMEM.
"""

import numpy as np

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def bilinear_matrix(dst: int, src: int) -> np.ndarray:
    """Half-pixel-centered bilinear interpolation weights, [dst, src].

    For downscaling, applies the standard anti-aliased triangle kernel
    (support scaled by src/dst) so the result matches what a quality
    resizer produces; for upscaling it reduces to classic bilinear.
    Every row sums to 1.
    """
    if dst == src:
        return np.eye(dst, dtype=np.float32)
    scale = src / dst
    radius = max(1.0, scale)  # anti-alias when downscaling
    w = np.zeros((dst, src), dtype=np.float64)
    for d in range(dst):
        center = (d + 0.5) * scale - 0.5
        lo = int(np.floor(center - radius))
        hi = int(np.ceil(center + radius))
        for s in range(max(lo, 0), min(hi + 1, src)):
            t = abs(s - center) / radius
            if t < 1.0:
                w[d, s] = 1.0 - t
        row = w[d].sum()
        if row > 0:
            w[d] /= row
    return w.astype(np.float32)


def _resize_kernel(img_ref, wy_ref, wx_ref, o_ref):
    """One channel plane: o = wy @ img @ wx^T (two MXU contractions)."""
    img = img_ref[:, :, 0]  # [H_src, W_src]
    wy = wy_ref[...]        # [H_dst, H_src]
    wx = wx_ref[...]        # [W_dst, W_src]
    tmp = jnp.dot(wy, img, preferred_element_type=jnp.float32)
    o_ref[:, :, 0] = jnp.dot(tmp, wx.T, preferred_element_type=jnp.float32)


def resize_bilinear(img: jax.Array, wy: jax.Array, wx: jax.Array) -> jax.Array:
    """Pallas separable resize: [H_src, W_src, C] -> [H_dst, W_dst, C]."""
    hs, ws, c = img.shape
    hd = wy.shape[0]
    wd = wx.shape[0]
    return pl.pallas_call(
        _resize_kernel,
        grid=(c,),
        in_specs=[
            pl.BlockSpec((hs, ws, 1), lambda i: (0, 0, i)),
            pl.BlockSpec((hd, hs), lambda i: (0, 0)),
            pl.BlockSpec((wd, ws), lambda i: (0, 0)),
        ],
        out_specs=pl.BlockSpec((hd, wd, 1), lambda i: (0, 0, i)),
        out_shape=jax.ShapeDtypeStruct((hd, wd, c), jnp.float32),
        interpret=True,
    )(img, wy, wx)
