"""Pallas multi-head attention kernel — the attentive-critic hot-spot (L1).

The paper's critic distils the N agents' state embeddings through an
8-head attention network (Section V-B). During training this runs for
every critic, every agent, every minibatch row — it is the densest
compute inside `train_step`, so it is implemented as a Pallas kernel
and wired into the L2 critic with a custom VJP whose backward pass is a
second Pallas kernel. `interpret=True` everywhere: the CPU PJRT plugin
cannot execute Mosaic custom-calls, and correctness is validated against
`ref.mha_ref` by the pytest/hypothesis suite.

TPU mapping (DESIGN.md §Hardware-Adaptation): the grid tiles the batch
axis; each program holds a fat [BB, H, S, Dh] Q/K/V tile in VMEM,
computes batched QK^T on the MXU, a numerically-stable softmax on the
VPU, and the PV contraction back on the MXU. The batch-block size BB is
chosen so the tile stays inside a VMEM budget — with the paper's dims
(N=4 agents, embed 8, 8 heads) a BB=128 tile is 3×128×8×4×1×4B = 48 KiB
of input, far under the ~16 MiB/core budget, so the grid stays tiny and
(crucially for interpret mode, which runs grid programs sequentially)
the kernel is a handful of fat programs instead of thousands of slivers.
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

# VMEM-budgeted batch block: number of (batch*critic) rows per program.
DEFAULT_BLOCK_B = 128


def _block_b(b: int) -> int:
    """Largest divisor of b that is <= DEFAULT_BLOCK_B (grid must tile b)."""
    bb = min(b, DEFAULT_BLOCK_B)
    while b % bb != 0:
        bb -= 1
    return bb


def _softmax_rows(s):
    m = jnp.max(s, axis=-1, keepdims=True)
    e = jnp.exp(s - m)
    return e / jnp.sum(e, axis=-1, keepdims=True)


def _mha_fwd_kernel(q_ref, k_ref, v_ref, o_ref):
    """One [BB, H, S, Dh] tile: o = softmax(q k^T / sqrt(dh)) v."""
    q = q_ref[...]
    k = k_ref[...]
    v = v_ref[...]
    dh = q.shape[-1]
    scale = 1.0 / jnp.sqrt(jnp.float32(dh))
    s = jnp.einsum("bhqd,bhkd->bhqk", q, k,
                   preferred_element_type=jnp.float32) * scale
    p = _softmax_rows(s)
    o_ref[...] = jnp.einsum("bhqk,bhkd->bhqd", p, v,
                            preferred_element_type=jnp.float32)


def _mha_bwd_kernel(q_ref, k_ref, v_ref, do_ref, dq_ref, dk_ref, dv_ref):
    """One [BB, H, S, Dh] tile of the attention backward pass.

    Recomputes the probabilities (flash-style: cheaper than storing them)
    and applies the softmax VJP:
      dv = p^T do
      dp = do v^T
      ds = p * (dp - rowsum(dp * p))
      dq = ds k / sqrt(dh),  dk = ds^T q / sqrt(dh)
    """
    q = q_ref[...]
    k = k_ref[...]
    v = v_ref[...]
    do = do_ref[...]
    dh = q.shape[-1]
    scale = 1.0 / jnp.sqrt(jnp.float32(dh))
    s = jnp.einsum("bhqd,bhkd->bhqk", q, k,
                   preferred_element_type=jnp.float32) * scale
    p = _softmax_rows(s)
    dv = jnp.einsum("bhqk,bhqd->bhkd", p, do,
                    preferred_element_type=jnp.float32)
    dp = jnp.einsum("bhqd,bhkd->bhqk", do, v,
                    preferred_element_type=jnp.float32)
    ds = p * (dp - jnp.sum(dp * p, axis=-1, keepdims=True))
    dq_ref[...] = jnp.einsum("bhqk,bhkd->bhqd", ds, k,
                             preferred_element_type=jnp.float32) * scale
    dk_ref[...] = jnp.einsum("bhqk,bhqd->bhkd", ds, q,
                             preferred_element_type=jnp.float32) * scale
    dv_ref[...] = dv


def _tile_spec(bb: int, h: int, seq: int, dh: int) -> pl.BlockSpec:
    return pl.BlockSpec((bb, h, seq, dh), lambda i: (i, 0, 0, 0))


def _mha_fwd(q, k, v):
    b, h, s, dh = q.shape
    bb = _block_b(b)
    spec = _tile_spec(bb, h, s, dh)
    return pl.pallas_call(
        _mha_fwd_kernel,
        grid=(b // bb,),
        in_specs=[spec, spec, spec],
        out_specs=spec,
        out_shape=jax.ShapeDtypeStruct((b, h, s, dh), jnp.float32),
        interpret=True,
    )(q, k, v)


def _mha_bwd(q, k, v, do):
    b, h, s, dh = q.shape
    bb = _block_b(b)
    spec = _tile_spec(bb, h, s, dh)
    shape = jax.ShapeDtypeStruct((b, h, s, dh), jnp.float32)
    return pl.pallas_call(
        _mha_bwd_kernel,
        grid=(b // bb,),
        in_specs=[spec, spec, spec, spec],
        out_specs=[spec, spec, spec],
        out_shape=[shape, shape, shape],
        interpret=True,
    )(q, k, v, do)


@jax.custom_vjp
def mha(q: jax.Array, k: jax.Array, v: jax.Array) -> jax.Array:
    """Pallas multi-head attention: [B, H, S, Dh] -> [B, H, S, Dh].

    Differentiable: the VJP is the Pallas backward kernel above, so the
    whole train_step (including attention gradients) lowers into one HLO
    module with no Python on the training path.
    """
    return _mha_fwd(q, k, v)


def _mha_vjp_fwd(q, k, v):
    return _mha_fwd(q, k, v), (q, k, v)


def _mha_vjp_bwd(res, do):
    q, k, v = res
    return _mha_bwd(q, k, v, do)


mha.defvjp(_mha_vjp_fwd, _mha_vjp_bwd)
